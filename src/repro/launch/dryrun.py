import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.  For every (architecture x input shape x mesh) this lowers and
compiles the production step function against ShapeDtypeStruct stand-ins
(no allocation), then extracts:

  * memory_analysis()  — per-device bytes (fits / doesn't fit)
  * cost_analysis()    — HLO FLOPs + bytes accessed (roofline numerator)
  * collective bytes   — parsed from the post-SPMD HLO text per collective
                         kind (all-gather / all-reduce / reduce-scatter /
                         all-to-all / collective-permute)

and derives the three roofline terms (seconds) for TPU v5e:
  compute    = FLOPs_global / (chips * 197e12)
  memory     = bytes_global / (chips * 819e9)
  collective = coll_bytes_global / (chips * 50e9)

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out-dir benchmarks/results
"""
import argparse
import json
import re
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    mesh_n_agents,
    mesh_n_chips,
)
from repro.launch.sharding import (
    batch_pspec,
    cache_shardings,
    param_shardings,
    replicated,
)
from repro.launch.steps import (
    BayesTrainState,
    init_train_state,
    make_agent_cache,
    make_decode_step,
    make_prefill_step,
    make_train_round_step,
    serve_params,
)
from repro.optim import adam

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-device collective op output bytes by kind, from post-SPMD HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        for kind in COLLECTIVE_KINDS:
            # match op name at the start of the RHS expression, e.g.
            #   %ag = bf16[...] all-gather(...)
            m = re.search(r"\b" + kind + r"(-start|-done)?\(", rhs)
            if m and not rhs.startswith("fusion"):
                if m.group(1) == "-done":
                    break  # counted at -start
                # result type(s) appear before the op name
                type_part = rhs[: m.start()]
                b = _shape_bytes(type_part)
                out[kind]["count"] += 1
                out[kind]["bytes"] += b
                break
    return out


def count_params(shape_tree: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shape_tree))


def count_active_params(params_shape: Any, cfg) -> int:
    """Matmul-active params per token for the 6ND / 2ND estimate:
    * expert stacks scaled by top_k / n_experts (MoE active fraction),
    * the input embedding table is a gather (0 matmul FLOPs) unless tied,
      in which case it is counted once for the unembed matmul."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        n = int(np.prod(leaf.shape))
        if cfg.n_experts and "moe" in name and (
            "w_gate" in name or "w_up" in name or "w_down" in name
        ):
            n = n * cfg.top_k // cfg.n_experts
        if "embed" in name and "emb" in name and not cfg.tie_embeddings:
            n = 0  # pure gather
        total += n
    return total


def _with_shardings(shape_tree: Any, sharding_tree: Any) -> Any:
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shape_tree,
        sharding_tree,
    )


def input_specs(cfg, shape, mesh, *, mode: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    from jax.sharding import NamedSharding

    a = mesh_n_agents(mesh)
    # ceil-divide: when the global batch can't split across agents (e.g.
    # long_500k batch=1 on 2 pods) each pod serves its own replica of the
    # request; the effective global batch is a * b.
    b = max(1, -(-shape.global_batch // a))
    s = shape.seq_len

    def sds(shp, dtype):
        spec = batch_pspec(mesh, shp)
        return jax.ShapeDtypeStruct(shp, dtype, sharding=NamedSharding(mesh, spec))

    out: dict[str, Any] = {}
    if mode == "train":
        n_text = s
        if cfg.frontend == "vision_stub":
            n_text = s - cfg.n_patches
            out["patches"] = sds((a, b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.frontend == "audio_stub":
            out["frames"] = sds((a, b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        out["tokens"] = sds((a, b, n_text), jnp.int32)
        out["targets"] = sds((a, b, s if cfg.frontend == "vision_stub" else n_text), jnp.int32)
        # vlm targets cover the full (patch+text) logit range
        if cfg.frontend == "vision_stub":
            out["targets"] = sds((a, b, s), jnp.int32)
    elif mode == "prefill":
        n_text = s - (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
        out["tokens"] = sds((a, b, n_text), jnp.int32)
        if cfg.frontend == "vision_stub":
            out["patches"] = sds((a, b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.frontend == "audio_stub":
            out["frames"] = sds((a, b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    elif mode == "decode":
        out["tokens"] = sds((a, b, 1), jnp.int32)
        if cfg.frontend == "audio_stub":
            out["frames"] = sds((a, b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return out


def long_context_window_override(cfg, shape) -> int | None:
    """Dense/full-attention archs run long_500k only via the SWA variant."""
    if shape.name != "long_500k":
        return None
    if cfg.family in ("ssm", "hybrid"):
        return None  # native sub-quadratic
    return cfg.long_context_window


def dryrun_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    kv_quant: bool = False,
    no_remat: bool = False,
    consensus_impl: str = "einsum",
    consensus_wire_dtype: str = "",
    mesh_shape: tuple[int, int] | None = None,
    variant: str = "",
) -> dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()

    if shape.name == "long_500k" and not cfg.long_context_ok:
        return {
            "arch": arch,
            "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "status": "skipped",
            "reason": "full-attention enc-dec; long_500k out of family scope "
                      "(DESIGN.md §5)",
        }

    if mesh_shape is not None:
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    a = mesh_n_agents(mesh)
    chips = mesh_n_chips(mesh)
    window = long_context_window_override(cfg, shape)
    wire_dtype = {"": None, "f32": None, "bf16": jnp.bfloat16}[consensus_wire_dtype]
    cache_dtype = jnp.int8 if kv_quant else jnp.bfloat16

    from repro.models import init_params

    params_shape = jax.eval_shape(
        lambda k: jax.vmap(lambda kk: init_params(cfg, kk))(jax.random.split(k, a)),
        jax.random.key(0),
    )

    with mesh:
        if shape.kind == "train":
            opt = adam()
            W = jnp.full((a, a), 1.0 / a)
            state_shape = jax.eval_shape(
                lambda k: init_train_state(k, cfg, a, opt), jax.random.key(0)
            )
            state_shard = param_shardings(state_shape, mesh, agent_leading=True)
            step = make_train_round_step(
                cfg, W, opt=opt, remat=not no_remat,
                consensus_impl=consensus_impl,
                consensus_wire_dtype=wire_dtype,
                mesh=mesh,
                posterior_shardings=state_shard.posterior
                if consensus_impl == "ppermute" else None,
            )
            state_sds = _with_shardings(state_shape, state_shard)
            batch_sds = input_specs(cfg, shape, mesh, mode="train")
            key_sds = jax.ShapeDtypeStruct(
                jax.eval_shape(lambda: jax.random.key(0)).shape,
                jax.eval_shape(lambda: jax.random.key(0)).dtype,
                sharding=replicated(mesh),
            )
            lowered = jax.jit(step).lower(state_sds, batch_sds, key_sds)
            n_active = count_active_params(params_shape, cfg) // a
            flops_factor = 6.0
            tokens = shape.global_batch * shape.seq_len
        else:
            # serving paths use posterior-mean bf16 weights
            serve_shape = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype),
                params_shape,
            )
            serve_shard = param_shardings(serve_shape, mesh, agent_leading=True)
            serve_sds = _with_shardings(serve_shape, serve_shard)
            b_local = max(1, -(-shape.global_batch // a))
            capacity = shape.seq_len
            if window:
                capacity = min(capacity, window)
            cache_shape = jax.eval_shape(
                lambda: make_agent_cache(cfg, a, b_local, capacity, dtype=cache_dtype)
            )
            cache_shard = cache_shardings(cache_shape, mesh, agent_leading=True)
            cache_sds = _with_shardings(cache_shape, cache_shard)
            batch_sds = input_specs(cfg, shape, mesh, mode=shape.kind)
            if shape.kind == "prefill":
                step = make_prefill_step(cfg, window_override=window)
                lowered = jax.jit(step).lower(serve_sds, batch_sds, cache_sds)
                flops_factor = 2.0
                tokens = shape.global_batch * shape.seq_len
            else:  # decode
                step = make_decode_step(cfg, window_override=window)
                pos_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=replicated(mesh))
                frames_sds = batch_sds.get("frames")
                lowered = jax.jit(step, static_argnames=()).lower(
                    serve_sds, batch_sds["tokens"], pos_sds, cache_sds, frames_sds
                )
                flops_factor = 2.0
                tokens = shape.global_batch  # one token per sequence
            n_active = count_active_params(params_shape, cfg) // a

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analyses ----
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    coll_bytes_dev = sum(v["bytes"] for v in coll.values())

    flops_global = flops_dev * chips
    bytes_global = bytes_dev * chips
    coll_bytes_global = coll_bytes_dev * chips
    # RAW HLO terms.  CAVEAT (validated, see costmodel.py docstring): XLA
    # cost_analysis counts while-loop bodies ONCE, so these undercount
    # anything inside the layer/chunk scans by the trip counts.  They remain
    # exact for ops outside the scans (consensus collectives, embed/unembed)
    # and for relative comparisons of same-structure programs.
    t_compute = flops_global / (chips * PEAK_FLOPS_BF16)
    t_memory = bytes_global / (chips * HBM_BW)
    t_coll = coll_bytes_global / (chips * ICI_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}

    # ANALYTIC terms (trip-count-correct): the §Roofline table's source.
    from repro.launch.costmodel import analytic_costs

    analytic = analytic_costs(
        cfg,
        mode=shape.kind,
        batch_global=(max(1, -(-shape.global_batch // a))) * a,
        seq_len=shape.seq_len,
        n_agents=a,
        data_shards=mesh.shape["data"],
        model_shards=mesh.shape["model"],
        n_matmul_params=n_active,
        n_total_params=count_params(params_shape) // a,
        window=window,
        kv_bytes=1.0 + 4.0 / cfg.hd if kv_quant else 2.0,
    )
    dominant = analytic["dominant"]

    model_flops = flops_factor * n_active * tokens
    useful_ratio = model_flops / flops_global if flops_global else 0.0

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant,
        "mesh_shape": dict(mesh.shape),
        "kv_quant": kv_quant,
        "consensus_impl": consensus_impl,
        "consensus_wire_dtype": consensus_wire_dtype or "f32",
        "status": "ok",
        "n_agents": a,
        "chips": chips,
        "window_override": window,
        "params_per_agent": count_params(params_shape) // a,
        "active_params_per_agent": n_active,
        "tokens_per_step": tokens,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collectives": coll,
        "collective_bytes_per_device": coll_bytes_dev,
        "hlo_roofline_seconds": terms,  # raw HLO (scan-undercounted, see caveat)
        "roofline_seconds": analytic["roofline_seconds"],  # analytic, primary
        "analytic": analytic,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": flops_global,
        "useful_flops_ratio": useful_ratio,
        "memory_analysis": mem_info,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="benchmarks/results")
    # §Perf variant knobs
    ap.add_argument("--variant", default="", help="tag for the output filename")
    ap.add_argument("--kv-quant", action="store_true", help="int8 KV cache")
    ap.add_argument("--no-remat", action="store_true", help="disable activation rematerialization")
    ap.add_argument("--consensus-impl", default="einsum", choices=["einsum", "ppermute", "none"])
    ap.add_argument("--consensus-dtype", default="", choices=["", "f32", "bf16"])
    ap.add_argument("--mesh-shape", default="", help="DxM single-pod override, e.g. 32x8")
    args = ap.parse_args()
    mesh_shape = None
    if args.mesh_shape:
        d_, m_ = args.mesh_shape.split("x")
        mesh_shape = (int(d_), int(m_))

    combos = []
    if args.all:
        for arch in list_archs():
            if arch == "repro-100m":
                continue
            for shp in INPUT_SHAPES:
                combos.append((arch, shp))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        combos = [(args.arch, args.shape)]

    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for arch, shp in combos:
        tag = f"{arch}_{shp}_{'multi' if args.multi_pod else 'single'}"
        if args.variant:
            tag += f"_{args.variant}"
        try:
            res = dryrun_one(
                arch, shp, args.multi_pod,
                kv_quant=args.kv_quant,
                no_remat=args.no_remat,
                consensus_impl=args.consensus_impl,
                consensus_wire_dtype=args.consensus_dtype,
                mesh_shape=mesh_shape,
                variant=args.variant,
            )
        except Exception as e:  # noqa: BLE001
            res = {
                "arch": arch, "shape": shp,
                "mesh": "multi" if args.multi_pod else "single",
                "status": "error", "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        path = os.path.join(args.out_dir, f"dryrun_{tag}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        dom = res.get("dominant", "-")
        print(
            f"[{res['status']:7s}] {arch:26s} {shp:12s} "
            f"mesh={res['mesh']:6s} dominant={dom} "
            f"compile={res.get('compile_s', '-')}s",
            flush=True,
        )
        if res["status"] == "error":
            print("   ", res["error"], flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
