"""Hand-scheduled expert-parallel MoE via shard_map + all_to_all
(EXPERIMENTS.md §Perf beyond-paper optimization).

The baseline MoE (models/moe.py) is pure jnp: capacity dispatch by
gather/scatter with the expert dim sharded over ``model`` — GSPMD inserts
whatever collectives it infers (usually all-gathers of the dispatch
buffers).  This module is the explicit schedule production MoE systems use:

  tokens sharded over (data x model)  ->  route locally  ->  build per-
  destination-shard capacity buffers  ->  ALL_TO_ALL over ``model``  ->
  local expert FFN (E/m experts per shard)  ->  ALL_TO_ALL back  ->
  weighted combine.

Wire bytes per device: 2 x (m-1)/m x k x T_dev x d — independent of E, and
strictly the routed payload (the GSPMD path can gather full activations).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.moe import _capacity, load_balance_loss, route_topk


def _dispatch_to_buffers(x, expert_of, w_of, keep, n_dst, cap, experts_per_dst):
    """Build [n_dst, cap, ...] send buffers from flat assignments.

    Returns (x_buf [n_dst, cap, d], meta_buf [n_dst, cap, 3]) where meta =
    (source flat-assignment index + 1, local expert id, weight)."""
    t_k = expert_of.shape[0]
    dst = expert_of // experts_per_dst
    local_e = expert_of % experts_per_dst
    # slot within (dst): running count of prior assignments to the same dst
    onehot = jax.nn.one_hot(dst, n_dst, dtype=jnp.int32)  # [T*k, n_dst]
    slot = (jnp.cumsum(onehot, axis=0) * onehot - 1).max(axis=-1)
    ok = keep & (slot >= 0) & (slot < cap)
    dst_s = jnp.where(ok, dst, 0)
    slot_s = jnp.where(ok, slot, 0)
    x_buf = jnp.zeros((n_dst, cap, x.shape[-1]), x.dtype)
    x_buf = x_buf.at[dst_s, slot_s].add(
        jnp.where(ok[:, None], x, 0.0)
    )
    meta = jnp.zeros((n_dst, cap, 3), jnp.float32)
    src_idx = jnp.arange(t_k, dtype=jnp.float32) + 1.0
    meta = meta.at[dst_s, slot_s, 0].add(jnp.where(ok, src_idx, 0.0))
    meta = meta.at[dst_s, slot_s, 1].add(jnp.where(ok, local_e.astype(jnp.float32), 0.0))
    meta = meta.at[dst_s, slot_s, 2].add(jnp.where(ok, w_of, 0.0))
    return x_buf, meta


def moe_ffn_expert_parallel(
    params, x: jax.Array, cfg, mesh, *, axis: str = "model", dtype=None
):
    """Expert-parallel MoE FFN.  x: [B, S, D] sharded over ("data", axis) on
    the flattened token dim; expert weights sharded over ``axis`` on the E
    dim.  Returns (y [B, S, D], aux)."""
    dtype = dtype or x.dtype
    m = mesh.shape[axis]
    e = cfg.n_experts
    assert e % m == 0, "experts must divide the expert-parallel axis"
    e_loc = e // m
    b, s, d = x.shape

    tok_spec = P(("data", axis), None)
    w_router_spec = P(None, None)
    w_e_spec = P(axis, None, None)

    def shard_fn(xt, w_router, w_gate, w_up, w_down):
        # xt: [T_dev, d]; w_*: [e_loc, ...] local experts
        t_dev = xt.shape[0]
        cap = _capacity(t_dev, m, cfg.top_k, cfg.capacity_factor)
        logits = xt @ w_router.astype(xt.dtype)
        weights, idx, probs = route_topk(logits, cfg.top_k)
        aux = load_balance_loss(probs, idx, e)
        expert_of = idx.reshape(-1)
        token_of = jnp.repeat(jnp.arange(t_dev), cfg.top_k)
        w_of = weights.reshape(-1)
        x_src = xt[token_of]
        keep = jnp.ones_like(expert_of, bool)
        x_buf, meta = _dispatch_to_buffers(
            x_src, expert_of, w_of, keep, m, cap, e_loc
        )
        # ---- all_to_all: send each destination shard its buffer ----
        x_recv = jax.lax.all_to_all(x_buf, axis, 0, 0, tiled=False)  # [m, cap, d]
        meta_recv = jax.lax.all_to_all(meta, axis, 0, 0, tiled=False)
        xr = x_recv.reshape(m * cap, d)
        local_e = meta_recv[..., 1].reshape(m * cap).astype(jnp.int32)
        valid = meta_recv[..., 0].reshape(m * cap) > 0
        # local expert FFN via one-hot batched einsum over e_loc experts
        sel = jax.nn.one_hot(jnp.where(valid, local_e, 0), e_loc, dtype=xr.dtype)
        sel = sel * valid[:, None]
        xe = jnp.einsum("te,td->etd", sel, xr)  # [e_loc, m*cap, d] (zeros elsewhere)
        g = jnp.einsum("etd,edf->etf", xe, w_gate.astype(xr.dtype))
        u = jnp.einsum("etd,edf->etf", xe, w_up.astype(xr.dtype))
        y_e = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, w_down.astype(xr.dtype))
        y_flat = jnp.einsum("etd,te->td", y_e, sel)  # back to [m*cap, d]
        # ---- all_to_all back to the source shards ----
        y_send = y_flat.reshape(m, cap, d)
        y_back = jax.lax.all_to_all(y_send, axis, 0, 0, tiled=False)  # [m, cap, d]
        meta_back = jax.lax.all_to_all(meta_recv, axis, 0, 0, tiled=False)
        # combine: scatter-add into tokens with router weights
        src = meta_back[..., 0].reshape(m * cap)
        wgt = meta_back[..., 2].reshape(m * cap)
        tok = jnp.where(src > 0, token_of[jnp.maximum(src.astype(jnp.int32) - 1, 0)], t_dev)
        out = jnp.zeros((t_dev + 1, d), jnp.float32)
        out = out.at[tok].add(
            y_back.reshape(m * cap, d).astype(jnp.float32) * wgt[:, None]
        )
        return out[:t_dev].astype(dtype), aux[None]

    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(tok_spec, w_router_spec, w_e_spec, w_e_spec, w_e_spec),
        out_specs=(tok_spec, P(("data", axis))),
    )
    xt = x.reshape(b * s, d)
    y, aux = fn(xt, params["router"], params["w_gate"], params["w_up"],
                params["w_down"])
    return y.reshape(b, s, d), jnp.mean(aux)
