# The paper's primary contribution: the decentralized Bayesian learning rule
# (posteriors + log-pool consensus + graphs + Theorem-1 theory + the
# simulated multi-agent runtime).  Production distribution lives in launch/.
from repro.core.posterior import (
    FullCovGaussian,
    GaussianPosterior,
    consensus_all_agents,
    consensus_full_cov,
    consensus_mean_field,
    consensus_mean_only,
    init_posterior,
    kl_gaussian,
    linreg_bayes_update,
    softplus,
    softplus_inv,
)
from repro.core import discrete, graphs, theory
from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    consensus_flat,
    consensus_flat_sparse,
    flat_posterior_from_pytree,
    init_flat_posterior,
    make_flat_nll,
    neighbor_tables,
)
from repro.core.simulated import (
    NetworkState,
    as_w_schedule,
    init_network,
    make_round_fn,
    run_rounds,
)

__all__ = [
    "FlatLayout",
    "FlatPosterior",
    "consensus_flat",
    "consensus_flat_sparse",
    "flat_posterior_from_pytree",
    "init_flat_posterior",
    "make_flat_nll",
    "neighbor_tables",
    "FullCovGaussian",
    "GaussianPosterior",
    "consensus_all_agents",
    "consensus_full_cov",
    "consensus_mean_field",
    "consensus_mean_only",
    "init_posterior",
    "kl_gaussian",
    "linreg_bayes_update",
    "softplus",
    "softplus_inv",
    "discrete",
    "graphs",
    "theory",
    "NetworkState",
    "as_w_schedule",
    "init_network",
    "make_round_fn",
    "run_rounds",
]
