"""Simulated multi-agent runtime: the whole network lives on one host and
agents are a leading pytree axis stepped under ``vmap``.  This is the exact
execution model for the paper's CPU-scale experiments (4-agent linear
regression, 9-agent star/grid Bayesian NNs, 26/101-agent time-varying
networks) and the reference semantics against which the production
collective runtime (launch/) is tested.

One communication round at every agent i (Sec 2.1):
  1. draw a local batch (the data pipeline pre-slices u minibatches),
  2+3. u local Bayes-by-Backprop steps against the prior q_i^{(n-1)}
       (Remark 1 merges the Bayesian update and the projection),
  4+5. consensus: precision-weighted averaging with row W_i (eq. 6).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.flat import (
    FlatLayout,
    FlatPosterior,
    flat_posterior_from_pytree,
    make_flat_nll,
)
from repro.core.posterior import (
    GaussianPosterior,
    consensus_all_agents,
    consensus_mean_only,
)
from repro.optim import Optimizer
from repro.optim.schedules import Schedule
from repro.vi.bayes_by_backprop import NllFn, local_vi_steps

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NetworkState:
    """State of the whole N-agent network (leading axis N on every leaf)."""

    posterior: GaussianPosterior  # stacked over agents
    opt_state: Any
    step: jax.Array  # per-agent local step counter [N]
    round: jax.Array  # scalar communication-round counter


def init_network(
    key: jax.Array,
    n_agents: int,
    init_params_fn: Callable[[jax.Array], PyTree],
    opt: Optimizer,
    init_sigma: float = 0.05,
    shared_init: bool = True,
    flat: bool = True,
) -> NetworkState:
    """Paper Remark 7: agents use a SHARED initialization the first time the
    local models are trained (but never re-synchronize afterwards).  Set
    ``shared_init=False`` to study the divergent-initialization failure mode.

    The posterior is stored as a ``core.flat.FlatPosterior`` (contiguous
    [N, P] buffers) — the canonical runtime format: consensus runs as ONE
    fused network-wide pass and the optimizer state collapses to flat
    buffers too.  ``make_round_fn`` picks the layout up from the state
    automatically, so ``nll_fn`` keeps its pytree signature either way.

    ``flat=False`` keeps the legacy pytree ``GaussianPosterior`` network
    state (deprecated; the leaf-loop consensus reference stays reachable
    through ``consensus_all_agents`` on pytree posteriors).
    """
    from repro.core.posterior import init_posterior

    if not flat:
        warnings.warn(
            "init_network(flat=False) builds the deprecated pytree network "
            "state; the flat [N, P] posterior is the canonical runtime "
            "format since PR 1 (pytrees remain the model-apply boundary).",
            DeprecationWarning,
            stacklevel=2,
        )

    if shared_init:
        params = init_params_fn(key)
        stack = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (n_agents,) + p.shape), params
        )
    else:
        keys = jax.random.split(key, n_agents)
        stack = jax.vmap(init_params_fn)(keys)
    post = init_posterior(stack, init_sigma=init_sigma)
    if flat:
        post = flat_posterior_from_pytree(post, leading_axes=1)
    opt_state = opt.init(post)
    return NetworkState(
        posterior=post,
        opt_state=opt_state,
        step=jnp.zeros((n_agents,), jnp.int32),
        round=jnp.asarray(0, jnp.int32),
    )


def network_local_steps(
    posterior,
    prior,
    opt: Optimizer,
    opt_state,
    nll,
    batches,
    key: jax.Array,
    lr,
    step: jax.Array,
    n_samples: int = 1,
    kl_scale: float = 1.0,
):
    """The network-wide local phase: per-agent key split + ``local_vi_steps``
    under ``vmap`` — SHARED by the synchronous round (``make_round_fn``) and
    the gossip event window (``repro.gossip.engine``).  The two runtimes'
    bit-identity in the all-edges-active case hangs on sharing this exact
    key/step derivation, so extend it here rather than copying it.

    Returns (posterior', opt_state', per-agent mean losses [N]).
    """
    n_agents = step.shape[0]
    keys = jax.random.split(key, n_agents)

    def local(post_i, prior_i, opt_i, batches_i, key_i, step_i):
        return local_vi_steps(
            post_i,
            prior_i,
            opt,
            opt_i,
            nll,
            batches_i,
            key_i,
            lr,
            step_i,
            n_samples=n_samples,
            kl_scale=kl_scale,
        )

    return jax.vmap(local)(posterior, prior, opt_state, batches, keys, step)


def make_round_fn(
    nll_fn: NllFn,
    opt: Optimizer,
    lr_schedule: Schedule,
    n_mc_samples: int = 1,
    kl_scale: float = 1.0,
    consensus: str = "gaussian",
    param_layout: FlatLayout | None = None,
    wire_dtype=None,
):
    """Build the jittable per-round transition.

    round_fn(state, batches, W, key) -> (state', mean_loss_per_agent)
      batches: pytree, leaves [N, u, ...] — u local minibatches per agent
      W: [N, N] row-stochastic (may differ per round: time-varying networks)

    ``nll_fn`` keeps its pytree signature; when the network state holds a
    ``FlatPosterior`` the layout is read off the state and the nll is wrapped
    so the flat theta sample crosses to a pytree only at the model-apply
    boundary.  ``param_layout`` pre-binds that layout at build time (skips
    the per-trace wrap; required only when the state type is not known yet).
    ``wire_dtype`` compresses the gaussian consensus exchange
    (``consensus_all_agents``); f32/None is bitwise uncompressed.
    """
    if consensus not in ("gaussian", "mean_only", "none"):
        raise ValueError(f"unknown consensus mode {consensus!r}")
    if param_layout is not None:
        nll_fn = make_flat_nll(nll_fn, param_layout)

    def round_fn(state: NetworkState, batches: Any, W: jax.Array, key: jax.Array):
        nll = nll_fn
        if param_layout is None and isinstance(state.posterior, FlatPosterior):
            nll = make_flat_nll(nll_fn, state.posterior.layout)
        lr = lr_schedule(state.round)
        prior = state.posterior  # q_i^{(n-1)}: consensus result of last round
        post, opt_state, losses = network_local_steps(
            state.posterior, prior, opt, state.opt_state, nll, batches, key,
            lr, state.step, n_samples=n_mc_samples, kl_scale=kl_scale,
        )
        u = jax.tree.leaves(batches)[0].shape[1]
        if consensus == "gaussian":
            post = consensus_all_agents(post, W, wire_dtype=wire_dtype)
        elif consensus == "mean_only":
            # dataclasses.replace keeps the posterior's own type (and, for a
            # FlatPosterior, its static layout)
            post = dataclasses.replace(
                post,
                mean=consensus_mean_only(post.mean, W),
                rho=consensus_mean_only(post.rho, W),
            )
        # consensus == "none": isolated learning (paper Fig 1b baseline)
        new_state = NetworkState(
            posterior=post,
            opt_state=opt_state,
            step=state.step + u,
            round=state.round + 1,
        )
        return new_state, losses

    return round_fn


def as_w_schedule(
    w_schedule: Sequence[jax.Array] | jax.Array | Callable[[int], jax.Array],
) -> Callable[[int], jax.Array]:
    """Normalize the three accepted topology-schedule forms — a static W, a
    list cycled over rounds, or a round-indexed callable — to one
    ``Callable[[int], W]``.  Shared by ``run_rounds`` and ``api.Session``."""
    if callable(w_schedule):
        return w_schedule
    if isinstance(w_schedule, (list, tuple)):
        ws = list(w_schedule)
        if not ws:
            raise ValueError("empty W schedule")
        return lambda r: ws[r % len(ws)]
    return lambda r: w_schedule


def run_rounds(
    round_fn,
    state: NetworkState,
    batch_sampler: Callable[[jax.Array, int], Any],
    w_schedule: Sequence[jax.Array] | jax.Array | Callable[[int], jax.Array],
    n_rounds: int,
    key: jax.Array,
    eval_fn: Callable[[NetworkState], dict] | None = None,
    eval_every: int = 0,
    jit: bool = True,
) -> tuple[NetworkState, list[dict]]:
    """Python-level driver (rounds may have data-dependent W / eval hooks).

    batch_sampler(key, round_idx) -> batches pytree [N, u, ...]
    w_schedule: a single W, a list cycled over rounds, or a round-indexed
    ``Callable[[int], W]`` (first-class time-varying topologies).
    """
    fn = jax.jit(round_fn) if jit else round_fn
    history: list[dict] = []
    w_for_round = as_w_schedule(w_schedule)
    for r in range(n_rounds):
        key, k_batch, k_round = jax.random.split(key, 3)
        batches = batch_sampler(k_batch, r)
        state, losses = fn(state, batches, jnp.asarray(w_for_round(r)), k_round)
        if eval_every and ((r + 1) % eval_every == 0 or r == n_rounds - 1):
            rec = {"round": r + 1, "loss": float(jnp.mean(losses))}
            if eval_fn is not None:
                rec.update(eval_fn(state))
            history.append(rec)
    return state, history
