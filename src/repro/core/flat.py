"""Flat-buffer posterior representation — the canonical runtime format.

A ``FlatPosterior`` stores the whole network's mean-field Gaussian posterior
as TWO contiguous fp32 buffers:

    mean: [N_agents, P]     rho: [N_agents, P]

plus a cached, hashable ``FlatLayout`` that records, per model-parameter
leaf: key path, shape, dtype and its (offset, size) column span in the flat
buffer.  The layout is built ONCE (``FlatLayout.for_pytree``) and carried as
static pytree metadata; ``to_pytree``/``from_pytree`` are the only
conversion points and they lower to pure slice/reshape/cast ops that XLA
fuses into the surrounding computation (no data movement beyond the
unavoidable cast when a leaf is not fp32).

Layout contract (shared with ``kernels.consensus``; see that module's
docstring for the kernel-side half):
  * axis 0 = agent axis, axis 1 = flattened parameter axis, leaf-major in
    ``layout.specs`` order, fp32;
  * buffers are UNPADDED (P = exact parameter count); lane padding to the
    kernel BLOCK multiple happens inside the kernels and is sliced off
    before any value escapes (mean pads 0.0, rho pads 1.0 -> finite sigma);
  * per-leaf dtypes are recorded in the layout and restored on
    ``to_pytree`` (mixed-dtype pytrees never silently promote).

Why: the consensus round (paper eq. 6) is purely memory-bound; with the
posterior flat, the whole network round is ONE fused pass over [N, P]
(``kernels.consensus.consensus_fused_network`` on TPU, a single fused XLA
einsum elsewhere) instead of a Python loop over leaves doing ~6 elementwise
HBM round-trips each.  ``benchmarks/bench_consensus.py`` tracks the win in
``BENCH_consensus.json``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graphs as _graphs
from repro.core.numerics import (
    COMPUTE_DTYPE,
    canonical_wire_dtype,
    softplus,
    softplus_inv,
    softplus_inv_py,
    wire_roundtrip,
)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """One model-parameter leaf's slot in the flat buffer."""

    path: str  # jax key-path string, for error messages / checkpoint docs
    shape: tuple[int, ...]  # per-agent shape (leading agent axes stripped)
    dtype: str  # dtype NAME of the original leaf (name, not np .str — the
    #             numpy byte-string for bfloat16 is a lossy '<V2')
    offset: int  # start column in the flat buffer
    size: int  # number of scalars = prod(shape)


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Cached leaf layout: offsets/shapes/dtypes + the pytree structure.

    Hashable (usable as static pytree metadata / jit static argument).
    """

    specs: tuple[LeafSpec, ...]
    treedef: Any  # jax PyTreeDef (hashable)
    n_params: int  # P: total scalars per agent

    @classmethod
    def for_pytree(cls, tree: PyTree, leading_axes: int = 0) -> "FlatLayout":
        """Build the layout from an example pytree.

        ``leading_axes`` axes are stripped off every leaf shape (pass 1 for a
        network-stacked tree whose leaves are [N, ...]).
        """
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs, off = [], 0
        for path, leaf in leaves_with_path:
            shape = tuple(int(s) for s in leaf.shape[leading_axes:])
            size = int(np.prod(shape)) if shape else 1
            specs.append(
                LeafSpec(
                    path=jax.tree_util.keystr(path),
                    shape=shape,
                    dtype=jnp.dtype(leaf.dtype).name,
                    offset=off,
                    size=size,
                )
            )
            off += size
        return cls(specs=tuple(specs), treedef=treedef, n_params=off)

    # -- conversions ---------------------------------------------------------

    def flatten(self, tree: PyTree) -> jax.Array:
        """Pytree with leaves [*B, *spec.shape] -> fp32 buffer [*B, P].

        Any common leading batch shape B (e.g. the agent axis) is preserved.
        """
        leaves = self.treedef.flatten_up_to(tree)
        batch = None
        flat = []
        for spec, leaf in zip(self.specs, leaves):
            nb = leaf.ndim - len(spec.shape)
            b = tuple(leaf.shape[:nb])
            if tuple(leaf.shape[nb:]) != spec.shape or (batch not in (None, b)):
                raise ValueError(
                    f"leaf {spec.path}: shape {leaf.shape} does not match "
                    f"layout {spec.shape} (batch {batch})"
                )
            batch = b
            flat.append(leaf.reshape(b + (spec.size,)).astype(COMPUTE_DTYPE))
        return jnp.concatenate(flat, axis=-1)

    def unflatten(self, flat: jax.Array) -> PyTree:
        """fp32 buffer [*B, P] -> pytree with leaves [*B, *shape], cast back
        to each leaf's recorded dtype (mixed-dtype trees round-trip exactly
        in structure and dtype)."""
        if flat.shape[-1] != self.n_params:
            raise ValueError(
                f"buffer has {flat.shape[-1]} params, layout expects {self.n_params}"
            )
        b = tuple(flat.shape[:-1])
        leaves = [
            jax.lax.slice_in_dim(flat, s.offset, s.offset + s.size, axis=flat.ndim - 1)
            .reshape(b + s.shape)
            .astype(s.dtype)
            for s in self.specs
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    # -- checkpoint doc ------------------------------------------------------

    def to_doc(self) -> dict:
        """Self-describing msgpack-able doc (see checkpoint.io flat helpers)."""
        skeleton = jax.tree.unflatten(self.treedef, list(range(len(self.specs))))
        return {
            "n_params": self.n_params,
            "specs": [dataclasses.asdict(s) | {"shape": list(s.shape)} for s in self.specs],
            "skeleton": _encode_skeleton(skeleton),
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "FlatLayout":
        skeleton = _decode_skeleton(doc["skeleton"])
        treedef = jax.tree.structure(skeleton)
        specs = tuple(
            LeafSpec(
                path=s["path"],
                shape=tuple(s["shape"]),
                dtype=s["dtype"],
                offset=s["offset"],
                size=s["size"],
            )
            for s in doc["specs"]
        )
        return cls(specs=specs, treedef=treedef, n_params=doc["n_params"])


def _encode_skeleton(node):
    """Encode a dict/list/tuple/int skeleton as msgpack-able JSON-ish data
    (tuples tagged so they survive the round trip)."""
    if isinstance(node, dict):
        if not all(isinstance(k, str) for k in node):
            raise TypeError("FlatLayout checkpoint docs require str dict keys")
        return {k: _encode_skeleton(v) for k, v in node.items()}
    if isinstance(node, tuple):
        return {"__tuple__": [_encode_skeleton(v) for v in node]}
    if isinstance(node, list):
        return [_encode_skeleton(v) for v in node]
    if isinstance(node, int):
        return node
    raise TypeError(
        f"pytree node {type(node)} not supported in a self-describing flat "
        "checkpoint; restore with an explicit `like` tree instead"
    )


def _decode_skeleton(node):
    if isinstance(node, dict):
        if set(node) == {"__tuple__"}:
            return tuple(_decode_skeleton(v) for v in node["__tuple__"])
        return {k: _decode_skeleton(v) for k, v in node.items()}
    if isinstance(node, list):
        return [_decode_skeleton(v) for v in node]
    return node


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FlatPosterior:
    """Mean-field Gaussian posterior over flat buffers [*B, P].

    Duck-types ``GaussianPosterior`` (mean / rho / sigma / precision /
    sample / n_params) so the VI step, optimizers and KL are shared; the
    leading batch axes B are typically (N_agents,) at the network level and
    () inside the per-agent ``vmap``.
    """

    mean: jax.Array
    rho: jax.Array
    layout: FlatLayout = dataclasses.field(metadata=dict(static=True))

    def sigma(self) -> jax.Array:
        return softplus(self.rho)

    def precision(self) -> jax.Array:
        return 1.0 / jnp.square(softplus(self.rho))

    def sample(self, key: jax.Array) -> jax.Array:
        """Reparameterized sample theta = mu + sigma * eps — a FLAT [*B, P]
        vector; feed it to the model through ``layout.unflatten`` (or use
        ``make_flat_nll`` which does exactly that at the apply boundary)."""
        eps = jax.random.normal(key, self.mean.shape, self.mean.dtype)
        return self.mean + softplus(self.rho) * eps

    def sample_pytree(self, key: jax.Array) -> PyTree:
        return self.layout.unflatten(self.sample(key))

    def n_params(self) -> int:
        return self.layout.n_params

    # -- serving-snapshot views (ROADMAP "Serving") --------------------------

    def astype(self, dtype) -> "FlatPosterior":
        """Both buffers cast to ``dtype`` (layout unchanged) — the decode
        half of the serving-snapshot path: a narrow-resident snapshot is
        ``astype(jnp.float32)``-ed inside the jitted apply, where XLA fuses
        the widening cast into the first read (no extra HBM pass).  A
        same-dtype cast is a structural no-op returning ``self``."""
        dt = jnp.dtype(dtype)
        if (jnp.dtype(self.mean.dtype) == dt
                and jnp.dtype(self.rho.dtype) == dt):
            return self
        return FlatPosterior(
            mean=self.mean.astype(dt), rho=self.rho.astype(dt),
            layout=self.layout,
        )

    def snapshot(self, dtype=None) -> "FlatPosterior":
        """A DECOUPLED copy of both buffers (optionally resident in a
        narrower dtype — ``core.numerics`` wire-dtype names; ``"bf16"``
        halves the snapshot HBM).  This is the publish half of the serving
        tier's double buffer (``repro.serve``): the returned posterior
        shares no storage with the training buffers, so subsequent training
        updates can never change what a reader serves, and the copy only
        READS the live buffers — a training run with a snapshot reader
        attached stays bitwise identical to one without."""
        from repro.core.numerics import canonical_wire_dtype

        dt = canonical_wire_dtype(dtype)
        return FlatPosterior(
            mean=jnp.array(self.mean, dtype=dt, copy=True),
            rho=jnp.array(self.rho, dtype=dt, copy=True),
            layout=self.layout,
        )

    def to_pytree(self):
        """-> ``GaussianPosterior`` over the original parameter pytree."""
        from repro.core.posterior import GaussianPosterior

        return GaussianPosterior(
            mean=self.layout.unflatten(self.mean),
            rho=self.layout.unflatten(self.rho.astype(COMPUTE_DTYPE)),
        )


def flat_posterior_from_pytree(post, layout: FlatLayout | None = None,
                               leading_axes: int = 1) -> FlatPosterior:
    """``GaussianPosterior`` (leaves [*B, ...]) -> ``FlatPosterior``.

    Pass a prebuilt ``layout`` to skip re-deriving it (it never changes for a
    fixed model, so build it once at setup time)."""
    if layout is None:
        layout = FlatLayout.for_pytree(post.mean, leading_axes=leading_axes)
    return FlatPosterior(
        mean=layout.flatten(post.mean), rho=layout.flatten(post.rho), layout=layout
    )


def init_flat_posterior(
    params: PyTree,
    init_sigma: float = 0.05,
    layout: FlatLayout | None = None,
    leading_axes: int = 0,
) -> FlatPosterior:
    """Flat analogue of ``init_posterior``: mean = flatten(params), constant
    rho = softplus^-1(init_sigma)."""
    if layout is None:
        layout = FlatLayout.for_pytree(params, leading_axes=leading_axes)
    mean = layout.flatten(params)
    rho = jnp.full_like(mean, softplus_inv_py(init_sigma))
    return FlatPosterior(mean=mean, rho=rho, layout=layout)


def make_flat_nll(nll_fn: Callable[[PyTree, Any], jax.Array], layout: FlatLayout):
    """Wrap a pytree-parameter nll into one taking a flat theta [P] — the
    single model-apply-boundary conversion of the flat runtime."""

    def flat_nll(theta_flat: jax.Array, batch: Any) -> jax.Array:
        return nll_fn(layout.unflatten(theta_flat), batch)

    return flat_nll


# ---------------------------------------------------------------------------
# Network-wide consensus over the flat buffers
# ---------------------------------------------------------------------------


XLA_BLOCK = 16384  # CPU cache-blocking width (lanes) for the XLA path
_MAX_UNROLL = 256  # cap on unrolled column blocks (graph-size guard)


def _eq6_block(W, mean, rho, wire_dtype=jnp.float32):
    """Eq. (6) on one [N, BLOCK] column block (identical math to the Pallas
    network kernel body, including the exchange-boundary wire rounding —
    ``wire_roundtrip`` is a structural no-op at f32)."""
    prec = 1.0 / jnp.square(softplus(rho))
    prec_x = wire_roundtrip(prec, wire_dtype)
    pm_x = wire_roundtrip(prec * mean, wire_dtype)
    new_prec = jnp.matmul(W, prec_x, preferred_element_type=COMPUTE_DTYPE)
    new_pm = jnp.matmul(W, pm_x, preferred_element_type=COMPUTE_DTYPE)
    return new_pm / new_prec, softplus_inv(jax.lax.rsqrt(new_prec))


def consensus_flat_reference(
    mean: jax.Array,
    rho: jax.Array,
    W: jax.Array,
    block: int = XLA_BLOCK,
    active: jax.Array | None = None,
    wire_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Eq. (6) on the flat [N, P] buffers — the reference semantics for the
    Pallas kernels and the fast non-TPU path.

    Processed in unrolled column blocks of ``block`` lanes, assembled with
    ``dynamic_update_slice`` (in-place after XLA copy elision): the block
    intermediates stay cache-resident and independent blocks schedule across
    CPU threads — a monolithic [N, P] matmul pair spills its intermediates
    to DRAM and measures ~2x slower, and a ``concatenate`` assembly costs
    more than the whole computation (measured on XLA:CPU; see
    BENCH_consensus.json).  Math is bitwise identical per block.

    ``active`` (the gossip event-window form, see
    ``consensus_flat_masked_reference``) selects per block between the
    computed row (active agents) and the ORIGINAL (mean, rho) row
    (inactive agents pass through bitwise); ``None`` adds no select at all.
    ``wire_dtype`` rounds (prec, prec*mu) at the exchange boundary
    (``kernels.consensus`` module docstring); f32/None is bitwise the
    uncompressed path.
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    act = None if active is None else (active > 0)[:, None]

    def blk(m_in, r_in):
        m_o, r_o = _eq6_block(W, m_in, r_in, wire_dtype)
        if act is None:
            return m_o, r_o
        return jnp.where(act, m_o, m_in), jnp.where(act, r_o, r_in)

    n, p = mean.shape
    if p <= block:
        return blk(mean, rho)
    n_blocks = -(-p // block)
    if n_blocks > _MAX_UNROLL:
        block = -(-p // _MAX_UNROLL)
    mean_out = jnp.empty_like(mean)
    rho_out = jnp.empty_like(rho)
    for s in range(0, p, block):
        e = min(s + block, p)
        m_o, r_o = blk(mean[:, s:e], rho[:, s:e])
        mean_out = jax.lax.dynamic_update_slice(mean_out, m_o, (0, s))
        rho_out = jax.lax.dynamic_update_slice(rho_out, r_o, (0, s))
    return mean_out, rho_out


def consensus_flat(
    posts: FlatPosterior,
    W: jax.Array,
    *,
    mode: str | None = None,
    block: int | None = None,
    wire_dtype=None,
) -> FlatPosterior:
    """Single fused network-wide consensus (eq. 6) on a ``FlatPosterior``.

    mode:
      None        auto — Pallas kernel on TPU, fused XLA einsum elsewhere
      "pallas"    the Pallas network kernel (compiled on TPU, interpreted
                  elsewhere — SLOW off-TPU, correctness checks only)
      "interpret" force the Pallas interpreter
      "xla"       force the fused XLA reference path

    ``wire_dtype`` (``None`` | ``"f32"|"bf16"|"f16"`` | dtype) rounds the
    exchanged (prec, prec*mu) through the wire dtype on every mode —
    f32/None is bitwise the uncompressed path (ROADMAP "Wire precision").
    """
    from repro.kernels.consensus import DEFAULT_BLOCK, consensus_fused_network

    if mode is None:
        mode = "pallas" if jax.default_backend() == "tpu" else "xla"
    if mode == "xla":
        mean, rho = consensus_flat_reference(
            posts.mean, posts.rho, W,
            block=(XLA_BLOCK if block is None else block),
            wire_dtype=wire_dtype,
        )
    elif mode in ("pallas", "interpret"):
        mean, rho = consensus_fused_network(
            W, posts.mean, posts.rho,
            block=(DEFAULT_BLOCK if block is None else block),
            interpret=(True if mode == "interpret" else None),
            wire_dtype=canonical_wire_dtype(wire_dtype),
        )
    else:
        raise ValueError(f"unknown consensus_flat mode {mode!r}")
    return FlatPosterior(mean=mean, rho=rho, layout=posts.layout)


def consensus_flat_masked_reference(
    mean: jax.Array,
    rho: jax.Array,
    W: jax.Array,
    active: jax.Array,
    block: int = XLA_BLOCK,
    wire_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Masked (event-window) eq. (6) on the flat buffers — reference
    semantics for ``consensus_fused_masked`` and the fast non-TPU path.

    The shared blocked loop of ``consensus_flat_reference`` with the
    activity select: active agents get the computed row, inactive ones
    their ORIGINAL (mean, rho) row.  With ``active`` all-true the select is
    the identity on the computed values, so the output is bit-identical to
    the unmasked reference (the gossip/synchronous equivalence contract,
    which ``wire_dtype`` preserves: both paths round at the same exchange
    boundary).
    """
    return consensus_flat_reference(
        mean, rho, W, block=block, active=active, wire_dtype=wire_dtype
    )


def consensus_flat_masked(
    posts: FlatPosterior,
    W: jax.Array,
    active: jax.Array,
    *,
    mode: str | None = None,
    block: int | None = None,
    mesh: Any = None,
    axis: str = "agents",
    window: Any = None,
    wire_dtype=None,
) -> FlatPosterior:
    """Masked network-wide consensus for one gossip event window.

    ``W`` is the window's effective W-tilde and ``active`` its [N] activity
    mask (``repro.gossip.clocks.EventWindow``).  Active agents merge per
    eq. (6); inactive agents pass through bit-identically (no softplus
    round trip — an idle agent's posterior is bit-stable across windows).
    Same mode semantics as ``consensus_flat``, plus the mesh-aware form:

      "ppermute"  execute the window SHARDED over the agent axis of ``mesh``
                  (``launch.consensus_opt.consensus_ppermute_window``): one
                  ``shard_map`` over the [N, P] buffers that ppermutes only
                  the window's fired shard offsets.  Requires ``mesh`` and
                  the ``window`` (its static edge list IS the permutation
                  schedule); bit-identical to the "xla" path by test.

    ``wire_dtype`` rounds the exchanged (prec, prec*mu) on every mode —
    on the ppermute mode the rounded payload IS the ppermuted wire traffic
    (halved ICI bytes at bf16); f32/None is bitwise uncompressed.
    """
    from repro.kernels.consensus import DEFAULT_BLOCK, consensus_fused_masked

    if mode == "ppermute":
        from repro.launch.consensus_opt import consensus_ppermute_window

        if mesh is None or window is None:
            raise ValueError(
                "consensus_flat_masked(mode='ppermute') needs mesh= and "
                "window= (the EventWindow's edges are the static "
                "permutation schedule)"
            )
        return consensus_ppermute_window(
            posts, window, mesh, axis,
            block=(XLA_BLOCK if block is None else block),
            wire_dtype=wire_dtype,
        )
    if mode is None:
        mode = "pallas" if jax.default_backend() == "tpu" else "xla"
    if mode == "xla":
        mean, rho = consensus_flat_masked_reference(
            posts.mean, posts.rho, W, active,
            block=(XLA_BLOCK if block is None else block),
            wire_dtype=wire_dtype,
        )
    elif mode in ("pallas", "interpret"):
        mean, rho = consensus_fused_masked(
            W, active, posts.mean, posts.rho,
            block=(DEFAULT_BLOCK if block is None else block),
            interpret=(True if mode == "interpret" else None),
            wire_dtype=canonical_wire_dtype(wire_dtype),
        )
    else:
        raise ValueError(f"unknown consensus_flat_masked mode {mode!r}")
    return FlatPosterior(mean=mean, rho=rho, layout=posts.layout)


def consensus_flat_delayed(
    posts: FlatPosterior,
    W: jax.Array,
    active: jax.Array,
    edges: jax.Array,
    weights: jax.Array,
    lags: jax.Array,
    hist_mean: jax.Array,
    hist_rho: jax.Array,
    round_idx: jax.Array,
    wire_dtype=None,
) -> FlatPosterior:
    """Delivery-latency eq. (6): one gossip window whose events merge STALE
    source posteriors (``repro.gossip.clocks.DelayedClock``).

    Event k = ``(dst, src) = edges[k]`` with mixing weight ``weights[k]``
    delivers src's posterior as of fire time — window ``round_idx -
    lags[k]`` — read from the [K, N, P] history ring buffer (slot ``r mod
    K``; the engine writes each window's post-local-step, pre-merge
    posterior into its slot BEFORE calling this, so a lag-0 event reads the
    current posterior and the all-lags-zero window reproduces the instant-
    delivery semantics).  Per eq. (6) each active dst accumulates

        prec_out[dst] = W[dst,dst] * prec_now[dst]
                        + sum_k w_k * prec(hist[slot_k, src_k])

    via a segment scatter-add over the static [E_max] event list (pad slots
    carry weight 0.0 and contribute exactly nothing); inactive rows pass
    through bitwise as in ``consensus_flat_masked``.

    ``wire_dtype`` rounds every accumulated (prec, prec*mu) contribution —
    the delivered stale statistics AND the self term, mirroring the dense
    kernels where the whole buffer crosses the exchange boundary — and the
    scatter-add accumulates fp32.  The history ring may be resident in a
    narrower dtype (``GossipEngine`` ``history_dtype``); gathered rows are
    decoded to fp32 before any math.  f32/None is bitwise uncompressed.
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    k_slots = hist_mean.shape[0]
    slot = jnp.mod(round_idx - lags, k_slots)  # [E]
    dst, src = edges[:, 0], edges[:, 1]
    # decode from the (possibly bf16-resident) history ring; no-op at f32
    h_mean = hist_mean[slot, src].astype(COMPUTE_DTYPE)  # [E, P] stale rows
    h_rho = hist_rho[slot, src].astype(COMPUTE_DTYPE)
    prec_e = 1.0 / jnp.square(softplus(h_rho))
    w_e = weights[:, None].astype(COMPUTE_DTYPE)
    prec_now = 1.0 / jnp.square(softplus(posts.rho))
    diag = jnp.diagonal(W)[:, None].astype(COMPUTE_DTYPE)
    if wire_dtype == jnp.float32:
        # pre-wire op order, verbatim — f32 stays bitwise identical
        acc_prec = (diag * prec_now).at[dst].add(w_e * prec_e)
        acc_pm = (diag * prec_now * posts.mean).at[dst].add(
            w_e * prec_e * h_mean
        )
    else:
        prec_now_x = wire_roundtrip(prec_now, wire_dtype)
        pm_now_x = wire_roundtrip(prec_now * posts.mean, wire_dtype)
        prec_e_x = wire_roundtrip(prec_e, wire_dtype)
        pm_e_x = wire_roundtrip(prec_e * h_mean, wire_dtype)
        acc_prec = (diag * prec_now_x).at[dst].add(w_e * prec_e_x)
        acc_pm = (diag * pm_now_x).at[dst].add(w_e * pm_e_x)
    act = (active > 0)[:, None]
    mean_out = jnp.where(act, acc_pm / acc_prec, posts.mean)
    rho_out = jnp.where(
        act, softplus_inv(jax.lax.rsqrt(acc_prec)), posts.rho
    )
    return FlatPosterior(mean=mean_out, rho=rho_out, layout=posts.layout)


# Peak [E, BLOCK] gather intermediate cap for the segment path (elements).
# 2^24 f32 elements = 64 MiB per buffer — cache-friendly on CPU, far below
# any [N, N] materialization at the population scales this path serves.
_SEGMENT_GATHER_ELEMS = 1 << 24


def consensus_flat_segments(
    posts: FlatPosterior,
    dst: jax.Array,
    src: jax.Array,
    weights: jax.Array,
    *,
    active: jax.Array | None = None,
    block: int | None = None,
    wire_dtype=None,
) -> FlatPosterior:
    """Edge-native eq. (6): segment-sum consensus over flat [E] edge arrays.

    The sparse-first counterpart of ``consensus_flat_reference`` — the graph
    arrives as ``(dst, src, weights)`` edge lists (self-loops INCLUDED, e.g.
    ``SparseGraph.edge_arrays()``), never as a dense ``[N, N]`` W.  Per lane
    block: gather each edge's source sufficient statistics, scatter-add
    (``segment_sum``) into the destination rows

        prec_out[i] = sum_{e: dst_e = i} w_e * prec_x[src_e]
        pm_out[i]   = sum_{e: dst_e = i} w_e * (prec * mu)_x[src_e]

    with the (prec, prec*mu) buffers rounded through ``wire_dtype`` at the
    exchange boundary exactly as in ``_eq6_block`` (structural no-op at
    f32) and fp32 accumulation throughout.  Peak memory is O(E * block):
    the default ``block`` shrinks with E so the gather intermediate stays
    under ``_SEGMENT_GATHER_ELEMS`` elements — no path here is O(N^2).

    Agrees with the dense reference elementwise to fp32 reduction-order
    tolerance on every wire dtype (the scatter accumulates in edge order,
    the matmul in column order); rows whose accumulation is a single term
    and the wire-rounded exchange values themselves are bitwise identical.

    Zero-weight pad edges (any valid dst/src) contribute exactly nothing,
    matching the ``consensus_flat_delayed`` event-list convention.
    ``active`` masks rows gossip-style: inactive rows pass through bitwise.
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    n, p = posts.mean.shape
    n_edges = int(dst.shape[0])
    w_e = weights[:, None].astype(COMPUTE_DTYPE)
    act = None if active is None else (active > 0)[:, None]
    if block is None:
        block = max(128, min(XLA_BLOCK, _SEGMENT_GATHER_ELEMS // max(n_edges, 1)))

    def blk(m_in, r_in):
        prec = 1.0 / jnp.square(softplus(r_in))
        prec_x = wire_roundtrip(prec, wire_dtype)
        pm_x = wire_roundtrip(prec * m_in, wire_dtype)
        acc_prec = jnp.zeros_like(prec).at[dst].add(w_e * prec_x[src])
        acc_pm = jnp.zeros_like(prec).at[dst].add(w_e * pm_x[src])
        m_o = acc_pm / acc_prec
        r_o = softplus_inv(jax.lax.rsqrt(acc_prec))
        if act is None:
            return m_o, r_o
        return jnp.where(act, m_o, m_in), jnp.where(act, r_o, r_in)

    if p <= block:
        mean_out, rho_out = blk(posts.mean, posts.rho)
        return FlatPosterior(mean=mean_out, rho=rho_out, layout=posts.layout)
    n_blocks = -(-p // block)
    if n_blocks > _MAX_UNROLL:
        block = -(-p // _MAX_UNROLL)
    mean_out = jnp.empty_like(posts.mean)
    rho_out = jnp.empty_like(posts.rho)
    for s in range(0, p, block):
        e = min(s + block, p)
        m_o, r_o = blk(posts.mean[:, s:e], posts.rho[:, s:e])
        mean_out = jax.lax.dynamic_update_slice(mean_out, m_o, (0, s))
        rho_out = jax.lax.dynamic_update_slice(rho_out, r_o, (0, s))
    return FlatPosterior(mean=mean_out, rho=rho_out, layout=posts.layout)


def consensus_flat_masked_sparse(
    posts: FlatPosterior,
    neighbors: jax.Array,
    weights: jax.Array,
    active: jax.Array,
    *,
    mode: str | None = None,
    block: int | None = None,
    wire_dtype=None,
) -> FlatPosterior:
    """Active-edge window consensus on CSR tables of the window's W-tilde
    (``neighbor_tables(window.w_eff)``): active agents read only their
    fired-neighbor rows, inactive agents copy their own row.  The "xla"
    path rebuilds the tiny dense W-tilde (reference semantics); the
    active-edge HBM saving exists on the Pallas path.  ``wire_dtype``
    rounds the gathered (prec, prec*mu) at the exchange boundary."""
    from repro.kernels.consensus import (
        DEFAULT_BLOCK,
        consensus_fused_masked_sparse,
    )

    if mode is None:
        mode = "pallas" if jax.default_backend() == "tpu" else "xla"
    if mode == "xla":
        mean, rho = _sparse_reference(
            posts.mean, posts.rho, neighbors, weights,
            block=(XLA_BLOCK if block is None else block), active=active,
            wire_dtype=wire_dtype,
        )
    elif mode in ("pallas", "interpret"):
        mean, rho = consensus_fused_masked_sparse(
            neighbors, weights, active, posts.mean, posts.rho,
            block=(DEFAULT_BLOCK if block is None else block),
            interpret=(True if mode == "interpret" else None),
            wire_dtype=canonical_wire_dtype(wire_dtype),
        )
    else:
        raise ValueError(f"unknown consensus_flat_masked_sparse mode {mode!r}")
    return FlatPosterior(mean=mean, rho=rho, layout=posts.layout)


def neighbor_tables(W: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """CSR-style padded neighbor tables for ``consensus_fused_sparse``.

    Returns (neighbors [N, D] int32, weights [N, D] float32), D = max
    in-degree.  Zero-weight entries of W are skipped; ragged rows are padded
    with the agent's own id at weight 0.0 (reads a tile the agent already
    touches, contributes nothing).  Host-side/static: call once per topology,
    not per round.

    Delegates to the one CSR construction
    (``graphs.SparseGraph.from_dense(...).neighbor_tables()``) shared with
    ``graphs.neighbor_lists`` / ``graphs.max_in_degree`` — sparse-native
    callers skip the dense bridge and call the method on their
    ``SparseGraph`` directly.
    """
    return _graphs.SparseGraph.from_dense(np.asarray(W)).neighbor_tables()


def _sparse_reference(mean, rho, neighbors, weights, block: int = XLA_BLOCK,
                      active=None, wire_dtype=None):
    """Sparse reference path: rebuild the (tiny, [N, N]) dense W from the
    neighbor tables and reuse the blocked dense path.  Bitwise-identical
    semantics (zero-weight entries contribute nothing; self-padded slots
    scatter-add 0.0 onto the diagonal), and far faster than row-gathers on
    XLA:CPU, whose gather lowers to a scalar loop.  The true deg(i)-tile
    HBM saving only exists on the Pallas path (mode="pallas" on TPU).
    ``active`` is the gossip event-window mask (see
    ``consensus_flat_reference``)."""
    n = mean.shape[0]
    rows = jnp.broadcast_to(jnp.arange(n, dtype=neighbors.dtype)[:, None], neighbors.shape)
    W = jnp.zeros((n, n), COMPUTE_DTYPE).at[rows, neighbors].add(weights)
    return consensus_flat_reference(
        mean, rho, W, block=block, active=active, wire_dtype=wire_dtype
    )


# ---------------------------------------------------------------------------
# Quarantine guard: fault-tolerant consensus (ROADMAP "Robustness")
# ---------------------------------------------------------------------------

# An exchanged |prec| or |prec*mu| lane above this is garbage regardless of
# finiteness (the "huge" corruption kind stays finite on purpose): a prec of
# 1e20 is a sigma of 1e-10 — far outside any posterior this runtime reaches.
QUARANTINE_BOUND = 1e20


def payload_validity(
    mean: jax.Array,
    rho: jax.Array,
    *,
    wire_dtype=None,
    bound: float = QUARANTINE_BOUND,
    mode: str | None = None,
    block: int | None = None,
) -> jax.Array:
    """[N] bool: is each agent's exchanged (prec, prec*mu) payload sane?

    The check runs ON THE WIRE REPRESENTATION — the rounded statistics a
    receiver actually sees (``wire_roundtrip``; structural no-op at f32):
    every lane must be finite, ``prec`` strictly positive, and both
    magnitudes within ``bound``.  This is the exchange-boundary guard the
    quarantined consensus wrappers apply to every incoming contribution; a
    single NaN/Inf/huge lane flags the whole agent (one poisoned lane
    already ruins its row of eq. (6)).

    mode: None auto (Pallas on TPU, XLA elsewhere) | "xla" | "pallas" |
    "interpret" — the fused kernel is pinned bit-equal to the reference.
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    if mode is None:
        mode = "pallas" if jax.default_backend() == "tpu" else "xla"
    if mode == "xla":
        prec = 1.0 / jnp.square(softplus(rho))
        prec_x = wire_roundtrip(prec, wire_dtype)
        pm_x = wire_roundtrip(prec * mean, wire_dtype)
        ok = (
            jnp.isfinite(prec_x)
            & (prec_x > 0.0)
            & (prec_x <= bound)
            & jnp.isfinite(pm_x)
            & (jnp.abs(pm_x) <= bound)
        )
        return jnp.all(ok, axis=-1)
    if mode in ("pallas", "interpret"):
        from repro.kernels.consensus import DEFAULT_BLOCK, payload_validity_fused

        return payload_validity_fused(
            mean, rho,
            bound=bound,
            block=(DEFAULT_BLOCK if block is None else block),
            interpret=(True if mode == "interpret" else None),
            wire_dtype=wire_dtype,
        )
    raise ValueError(f"unknown payload_validity mode {mode!r}")


def quarantine_w(W: jax.Array, valid: jax.Array) -> jax.Array:
    """Zero every column of an invalid source and move the dropped row mass
    onto self — rows stay row-stochastic, mirroring the clock layer's
    ``"conserve"`` rule for crashed agents.  The self column survives even
    for an invalid agent (its own row is restored post-consensus anyway).
    With ``valid`` all-True the result is value-identical to ``W``."""
    n = W.shape[0]
    eye = jnp.eye(n, dtype=bool)
    keep = valid[None, :] | eye
    Wk = jnp.where(keep, W, 0.0)
    dropped = jnp.sum(W - Wk, axis=1)
    return Wk.at[jnp.arange(n), jnp.arange(n)].add(dropped)


def _sanitized_sources(posts, mean_src, rho_src, valid_src, valid_self):
    """Exchange-side (mean, rho) with every invalid payload replaced by a
    finite placeholder.  Zeroing an invalid source's W column is NOT enough:
    ``0 * NaN = NaN`` still poisons the contraction, so the buffer rows
    behind zeroed weights must be finite too.  A corrupted-but-healthy
    sender falls back to its TRUE resident statistics (its self term stays
    truthful); an agent whose resident state is itself garbage gets a
    neutral (0, rho=1) row that only ever multiplies zero weight."""
    v_src = valid_src[:, None]
    v_self = valid_self[:, None]
    safe_mean = jnp.where(v_self, posts.mean, 0.0)
    safe_rho = jnp.where(v_self, posts.rho, 1.0)
    mean_x = jnp.where(v_src, mean_src, safe_mean)
    rho_x = jnp.where(v_src, rho_src, safe_rho)
    return mean_x, rho_x


def consensus_flat_masked_quarantined(
    posts: FlatPosterior,
    W: jax.Array,
    active: jax.Array,
    *,
    mean_src: jax.Array | None = None,
    rho_src: jax.Array | None = None,
    mode: str | None = None,
    block: int | None = None,
    mesh: Any = None,
    axis: str = "agents",
    window: Any = None,
    wire_dtype=None,
    bound: float = QUARANTINE_BOUND,
) -> tuple[FlatPosterior, jax.Array]:
    """Quarantine-guarded ``consensus_flat_masked``: validate every incoming
    contribution at the exchange boundary, drop invalid ones, move their row
    mass to self.  Returns ``(posterior, valid_src [N] bool)``.

    ``mean_src``/``rho_src`` are the statistics agents actually TRANSMIT
    (default: the resident ``posts`` buffers) — the fault-injection hook:
    the engine passes corrupted copies here while ``posts`` stays the
    resident truth.  The guard:

    * ``valid_src`` — wire-payload sanity of each transmission
      (``payload_validity``); invalid sources are dropped from every row
      (``quarantine_w``) and their buffer rows sanitized (``0 * NaN = NaN``
      would otherwise leak through the matmul);
    * a corrupted sender still MERGES (it is a bad transmitter, not a bad
      receiver): its own row mixes its true self term with its valid
      in-edges;
    * an agent whose RESIDENT state is invalid is excluded from merging
      and passes through unchanged (``Session.health`` flags it).

    With zero faults (all payloads valid) every branch is a value-identity
    (``where(True, x, .) = x``, ``W + 0 = W``), so the output is BITWISE
    identical to the unguarded path on every mode — the equivalence-ladder
    rung ``fault_policy="quarantine"`` == ``"strict"``.
    """
    mean_src = posts.mean if mean_src is None else mean_src
    rho_src = posts.rho if rho_src is None else rho_src
    vmode = mode if mode in ("pallas", "interpret") else "xla"
    valid_src = payload_validity(
        mean_src, rho_src, wire_dtype=wire_dtype, bound=bound, mode=vmode
    )
    valid_self = payload_validity(
        posts.mean, posts.rho, wire_dtype=wire_dtype, bound=bound, mode=vmode
    )
    mean_x, rho_x = _sanitized_sources(
        posts, mean_src, rho_src, valid_src, valid_self
    )
    posts_x = FlatPosterior(mean=mean_x, rho=rho_x, layout=posts.layout)
    W_g = quarantine_w(jnp.asarray(W, COMPUTE_DTYPE), valid_src)
    act_g = (active > 0) & valid_self
    if mode == "ppermute":
        from repro.launch.consensus_opt import consensus_ppermute_window

        if mesh is None or window is None:
            raise ValueError(
                "consensus_flat_masked_quarantined(mode='ppermute') needs "
                "mesh= and window="
            )
        out = consensus_ppermute_window(
            posts_x, window, mesh, axis,
            block=(XLA_BLOCK if block is None else block),
            wire_dtype=wire_dtype,
            w_eff=W_g, active=act_g,
        )
    else:
        out = consensus_flat_masked(
            posts_x, W_g, act_g,
            mode=mode, block=block, wire_dtype=wire_dtype,
        )
    v_self = valid_self[:, None]
    return (
        FlatPosterior(
            mean=jnp.where(v_self, out.mean, posts.mean),
            rho=jnp.where(v_self, out.rho, posts.rho),
            layout=posts.layout,
        ),
        valid_src,
    )


def consensus_flat_masked_sparse_quarantined(
    posts: FlatPosterior,
    neighbors: jax.Array,
    weights: jax.Array,
    active: jax.Array,
    *,
    mean_src: jax.Array | None = None,
    rho_src: jax.Array | None = None,
    mode: str | None = None,
    block: int | None = None,
    wire_dtype=None,
    bound: float = QUARANTINE_BOUND,
) -> tuple[FlatPosterior, jax.Array]:
    """Quarantine-guarded ``consensus_flat_masked_sparse``: the CSR-table
    form of the dense guard.  Table STRUCTURE stays static (same neighbor
    ids — gathering a sanitized zero-weight row is harmless); only the
    weights adjust in-graph: invalid non-self slots drop to 0.0 and each
    row's dropped mass lands on its real self slot.  Zero faults is a
    value-identity, as in the dense wrapper."""
    mean_src = posts.mean if mean_src is None else mean_src
    rho_src = posts.rho if rho_src is None else rho_src
    vmode = mode if mode in ("pallas", "interpret") else "xla"
    valid_src = payload_validity(
        mean_src, rho_src, wire_dtype=wire_dtype, bound=bound, mode=vmode
    )
    valid_self = payload_validity(
        posts.mean, posts.rho, wire_dtype=wire_dtype, bound=bound, mode=vmode
    )
    mean_x, rho_x = _sanitized_sources(
        posts, mean_src, rho_src, valid_src, valid_self
    )
    n = posts.mean.shape[0]
    rows = jnp.arange(n, dtype=neighbors.dtype)[:, None]
    self_mask = neighbors == rows
    keep = valid_src[neighbors] | self_mask
    wts_k = jnp.where(keep, weights, 0.0)
    dropped = jnp.sum(weights - wts_k, axis=1)
    # each row's REAL self entry (nonzero weight; pad slots are self at 0.0
    # and must not receive mass) absorbs the dropped in-weights
    self_slot = jnp.argmax(self_mask & (weights > 0.0), axis=1)
    wts_g = wts_k.at[jnp.arange(n), self_slot].add(dropped)
    act_g = (active > 0) & valid_self
    out = consensus_flat_masked_sparse(
        FlatPosterior(mean=mean_x, rho=rho_x, layout=posts.layout),
        neighbors, wts_g, act_g,
        mode=mode, block=block, wire_dtype=wire_dtype,
    )
    v_self = valid_self[:, None]
    return (
        FlatPosterior(
            mean=jnp.where(v_self, out.mean, posts.mean),
            rho=jnp.where(v_self, out.rho, posts.rho),
            layout=posts.layout,
        ),
        valid_src,
    )


def consensus_flat_delayed_quarantined(
    posts: FlatPosterior,
    W: jax.Array,
    active: jax.Array,
    edges: jax.Array,
    weights: jax.Array,
    lags: jax.Array,
    hist_mean: jax.Array,
    hist_rho: jax.Array,
    round_idx: jax.Array,
    *,
    corrupt: jax.Array | None = None,
    fill_mean: jax.Array | None = None,
    fill_rho: jax.Array | None = None,
    wire_dtype=None,
    bound: float = QUARANTINE_BOUND,
) -> tuple[FlatPosterior, jax.Array]:
    """Quarantine-guarded ``consensus_flat_delayed``: validate each DELIVERED
    event's stale (prec, prec*mu) contribution, drop invalid events (their
    weight moves to the dst's self term), keep agents with garbage resident
    state out of the merge.  Returns ``(posterior, valid_event [E] bool)``.

    ``corrupt``/``fill_mean``/``fill_rho`` ([N] arrays) inject sender-side
    corruption into the gathered history rows by src id — applied at
    DELIVERY time (the history ring itself stays clean; a flaky sender
    garbles whatever it transmits, however old).  Zero faults (no corrupt
    mask, all-finite history) is a value-identity against
    ``consensus_flat_delayed`` — the f32 branch keeps its op order verbatim.
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    k_slots = hist_mean.shape[0]
    slot = jnp.mod(round_idx - lags, k_slots)
    dst, src = edges[:, 0], edges[:, 1]
    h_mean = hist_mean[slot, src].astype(COMPUTE_DTYPE)
    h_rho = hist_rho[slot, src].astype(COMPUTE_DTYPE)
    if corrupt is not None:
        bad = corrupt[src][:, None]
        h_mean = jnp.where(bad, fill_mean[src][:, None], h_mean)
        h_rho = jnp.where(bad, fill_rho[src][:, None], h_rho)
    prec_e = 1.0 / jnp.square(softplus(h_rho))
    w_e = weights[:, None].astype(COMPUTE_DTYPE)
    prec_now = 1.0 / jnp.square(softplus(posts.rho))
    diag = jnp.diagonal(W)[:, None].astype(COMPUTE_DTYPE)

    # per-event wire-payload sanity of the delivered contribution
    prec_e_x = wire_roundtrip(prec_e, wire_dtype)
    pm_e_x = wire_roundtrip(prec_e * h_mean, wire_dtype)
    ok_e = (
        jnp.isfinite(prec_e_x)
        & (prec_e_x > 0.0)
        & (prec_e_x <= bound)
        & jnp.isfinite(pm_e_x)
        & (jnp.abs(pm_e_x) <= bound)
    )
    valid_e = jnp.all(ok_e, axis=-1)  # [E]
    v_e = valid_e[:, None]
    # dropped events: weight to the dst's self term, rows sanitized so the
    # zero weight never multiplies a non-finite lane
    w_e_g = jnp.where(v_e, w_e, 0.0)
    drop = jnp.zeros((posts.mean.shape[0], 1), COMPUTE_DTYPE).at[dst].add(
        w_e - w_e_g
    )
    diag_g = diag + drop
    prec_e = jnp.where(v_e, prec_e, 1.0)
    h_mean = jnp.where(v_e, h_mean, 0.0)
    valid_self = payload_validity(
        posts.mean, posts.rho, wire_dtype=wire_dtype, bound=bound, mode="xla"
    )
    if wire_dtype == jnp.float32:
        acc_prec = (diag_g * prec_now).at[dst].add(w_e_g * prec_e)
        acc_pm = (diag_g * prec_now * posts.mean).at[dst].add(
            w_e_g * prec_e * h_mean
        )
    else:
        prec_now_x = wire_roundtrip(prec_now, wire_dtype)
        pm_now_x = wire_roundtrip(prec_now * posts.mean, wire_dtype)
        prec_e_x = wire_roundtrip(prec_e, wire_dtype)
        pm_e_x = wire_roundtrip(prec_e * h_mean, wire_dtype)
        acc_prec = (diag_g * prec_now_x).at[dst].add(w_e_g * prec_e_x)
        acc_pm = (diag_g * pm_now_x).at[dst].add(w_e_g * pm_e_x)
    act = (active > 0) & valid_self
    act = act[:, None]
    mean_out = jnp.where(act, acc_pm / acc_prec, posts.mean)
    rho_out = jnp.where(
        act, softplus_inv(jax.lax.rsqrt(acc_prec)), posts.rho
    )
    return (
        FlatPosterior(mean=mean_out, rho=rho_out, layout=posts.layout),
        valid_e,
    )


def consensus_flat_segments_quarantined(
    posts: FlatPosterior,
    dst: jax.Array,
    src: jax.Array,
    weights: jax.Array,
    self_weight: jax.Array,
    *,
    active: jax.Array,
    mean_src: jax.Array | None = None,
    rho_src: jax.Array | None = None,
    block: int | None = None,
    wire_dtype=None,
    bound: float = QUARANTINE_BOUND,
) -> tuple[FlatPosterior, jax.Array]:
    """Quarantine-guarded ``consensus_flat_segments`` for edge-native event
    windows (``gossip.clocks.SparseWindow``): validate every FIRED edge's
    wire payload, drop invalid contributions (their weight moves to the
    dst's self term), keep agents with garbage resident state out of the
    merge.  Returns ``(posterior, valid_edge [E] bool)``.

    ``dst``/``src``/``weights`` are the window's fired NON-SELF edges
    (zero-weight pad slots allowed) and ``self_weight`` the per-agent
    conserve-rule self term; the guard adjusts both in-graph and then
    delegates to ``consensus_flat_segments`` over the same
    fired-then-self concatenation the engine's unguarded path builds — so
    with zero faults (all payloads valid) every argument is bitwise the
    unguarded call's and the output is BITWISE identical to it, the same
    equivalence-ladder rung the dense quarantined wrappers pin.

    ``mean_src``/``rho_src`` are the statistics agents actually TRANSMIT
    (the corruption-injection hook; default: the resident ``posts``).
    Mirroring ``consensus_flat_masked_quarantined``: an invalid
    transmission is dropped from every receiving row while the sender's
    own self term falls back to its TRUE resident statistics
    (``_sanitized_sources``); an agent whose RESIDENT state is invalid
    passes through unchanged.
    """
    wire_dtype = canonical_wire_dtype(wire_dtype)
    mean_src = posts.mean if mean_src is None else mean_src
    rho_src = posts.rho if rho_src is None else rho_src
    valid_src = payload_validity(
        mean_src, rho_src, wire_dtype=wire_dtype, bound=bound, mode="xla"
    )
    valid_self = payload_validity(
        posts.mean, posts.rho, wire_dtype=wire_dtype, bound=bound, mode="xla"
    )
    mean_x, rho_x = _sanitized_sources(
        posts, mean_src, rho_src, valid_src, valid_self
    )
    n = posts.mean.shape[0]
    valid_e = valid_src[src]  # [E] fired-edge wire validity
    w_e = weights.astype(COMPUTE_DTYPE)
    w_e_g = jnp.where(valid_e, w_e, 0.0)
    # dropped in-edge mass lands on the dst's self term — rows stay
    # row-stochastic, the segment form of quarantine_w's diagonal add
    drop = jnp.zeros((n,), COMPUTE_DTYPE).at[dst].add(w_e - w_e_g)
    w_self_g = self_weight.astype(COMPUTE_DTYPE) + drop
    ar = jnp.arange(n, dtype=dst.dtype)
    act_g = (active > 0) & valid_self
    out = consensus_flat_segments(
        FlatPosterior(mean=mean_x, rho=rho_x, layout=posts.layout),
        jnp.concatenate([dst, ar]),
        jnp.concatenate([src, ar]),
        jnp.concatenate([w_e_g, w_self_g]),
        active=act_g, block=block, wire_dtype=wire_dtype,
    )
    v_self = valid_self[:, None]
    return (
        FlatPosterior(
            mean=jnp.where(v_self, out.mean, posts.mean),
            rho=jnp.where(v_self, out.rho, posts.rho),
            layout=posts.layout,
        ),
        valid_e,
    )


def consensus_flat_sparse(
    posts: FlatPosterior,
    neighbors: jax.Array,
    weights: jax.Array,
    *,
    mode: str | None = None,
    block: int | None = None,
    wire_dtype=None,
) -> FlatPosterior:
    """Sparse-neighborhood consensus: agents read only their deg(i) neighbor
    rows (Pallas path).  Same mode/block/wire_dtype semantics as
    ``consensus_flat``: the block default is per-mode (XLA cache block vs
    kernel lane block); the "xla" path rebuilds the tiny dense W (reference
    semantics — the deg(i) traffic saving exists only on the Pallas
    path)."""
    from repro.kernels.consensus import DEFAULT_BLOCK, consensus_fused_sparse

    if mode is None:
        mode = "pallas" if jax.default_backend() == "tpu" else "xla"
    if mode == "xla":
        mean, rho = _sparse_reference(
            posts.mean, posts.rho, neighbors, weights,
            block=(XLA_BLOCK if block is None else block),
            wire_dtype=wire_dtype,
        )
    elif mode in ("pallas", "interpret"):
        mean, rho = consensus_fused_sparse(
            neighbors, weights, posts.mean, posts.rho,
            block=(DEFAULT_BLOCK if block is None else block),
            interpret=(True if mode == "interpret" else None),
            wire_dtype=canonical_wire_dtype(wire_dtype),
        )
    else:
        raise ValueError(f"unknown consensus_flat_sparse mode {mode!r}")
    return FlatPosterior(mean=mean, rho=rho, layout=posts.layout)
