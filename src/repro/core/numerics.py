"""Shared numerical primitives for Gaussian posteriors.

Single home for the softplus/softplus^-1 pair so the Pallas kernels, the
pure-jnp reference paths, and the core posterior code all use the SAME
stable formulation (previously the kernel inlined its own copy — satellite
fix of ISSUE 1).

``softplus_inv`` is stable over the full fp32 range of sigma:

* tiny y (sigma -> 0): softplus_inv(y) = log(expm1(y)) ~= log(y); the naive
  ``y + log1p(-exp(-y))`` form computes log1p(-exp(-eps)) which underflows
  ``-exp(-y)`` to -1 and returns -inf one ulp too early.  We use
  ``log(-expm1(-y)) + y`` which keeps full precision down to y ~ 1e-38.
* huge y (sigma >> 1): exp(-y) underflows to 0 and the result is exactly y,
  which is the correct asymptote (softplus(x) -> x for large x).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# canonical compute dtype for flat posterior buffers and kernel wrappers
COMPUTE_DTYPE = jnp.float32

# -- wire-dtype compression (ROADMAP "Wire precision") ----------------------
#
# The consensus round exchanges the sufficient statistics (prec, prec*mu);
# on the wire-bound paths those may travel compressed.  Contract: cast to
# the wire dtype AT THE EXCHANGE BOUNDARY, accumulate in fp32.  "f32" is a
# STRUCTURAL no-op — every helper below returns its input unchanged, so the
# f32 path emits the identical computation graph (bitwise identity with the
# pre-wire kernels, pinned by tests/test_wire_dtype.py).

WIRE_DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "f16": jnp.float16,
}

# unit roundoff u = eps/2 of round-to-nearest into the wire dtype: one
# cast perturbs each exchanged scalar by a relative error <= u.  The
# analytic error bound of a wire-compressed consensus output derives from
# u alone (tests/test_wire_dtype.py): new_prec is a convex combination of
# positive rounded terms (relative error <= u), new_pm accumulates
# |pm|-weighted roundoff, and the fp32 accumulation adds only O(eps_f32).
WIRE_UNIT_ROUNDOFF = {
    "f32": 0.0,
    "bf16": 2.0 ** -8,  # bf16: 7 stored mantissa bits, eps = 2^-7
    "f16": 2.0 ** -11,  # f16: 10 stored mantissa bits, eps = 2^-10
}


def canonical_wire_dtype(wire_dtype):
    """Normalize a wire-dtype spec (``None`` | ``"f32"|"bf16"|"f16"`` | a
    dtype-like) to the jnp dtype.  ``None`` means uncompressed (f32).
    Dtype-likes outside the supported wire set are rejected exactly like
    their string spellings (an int or f64 wire would silently corrupt the
    exchanged statistics instead of compressing them)."""
    if wire_dtype is None:
        return jnp.float32
    if isinstance(wire_dtype, str):
        if wire_dtype not in WIRE_DTYPES:
            raise ValueError(
                f"unknown wire_dtype {wire_dtype!r}; known: "
                f"{sorted(WIRE_DTYPES)}"
            )
        return WIRE_DTYPES[wire_dtype]
    dt = jnp.dtype(wire_dtype)
    for cand in WIRE_DTYPES.values():
        if dt == jnp.dtype(cand):
            return cand
    raise ValueError(
        f"unsupported wire_dtype {wire_dtype!r}; known: "
        f"{sorted(WIRE_DTYPES)} (or their dtypes)"
    )


def wire_dtype_name(wire_dtype) -> str:
    """The spec-string name of a wire dtype (inverse of
    ``canonical_wire_dtype``)."""
    dt = canonical_wire_dtype(wire_dtype)
    for name, cand in WIRE_DTYPES.items():
        if jnp.dtype(cand) == jnp.dtype(dt):
            return name
    raise ValueError(f"{wire_dtype!r} is not a supported wire dtype")


def wire_itemsize(wire_dtype) -> int:
    """Bytes per exchanged scalar at this wire dtype (cost-model input)."""
    return jnp.dtype(canonical_wire_dtype(wire_dtype)).itemsize


def wire_error_bound(wire_dtype) -> float:
    """Unit roundoff u of one cast into the wire dtype (0.0 for f32) — the
    scale of the derived consensus error bound (see WIRE_UNIT_ROUNDOFF)."""
    return WIRE_UNIT_ROUNDOFF[wire_dtype_name(wire_dtype)]


def wire_roundtrip(x: jax.Array, wire_dtype) -> jax.Array:
    """Round ``x`` through the wire dtype and decode back to its own dtype —
    the single-program simulation of a compressed exchange (the receiver
    accumulates in fp32 on the decoded values).  STRUCTURAL no-op for f32:
    returns ``x`` itself, so the uncompressed path's graph is untouched."""
    wd = canonical_wire_dtype(wire_dtype)
    if jnp.dtype(wd) == jnp.dtype(x.dtype):
        return x
    return x.astype(wd).astype(x.dtype)


def wire_cast_pair(prec: jax.Array, pm: jax.Array, wire_dtype):
    """Cast the (prec, prec*mu) sufficient-statistic pair to the wire dtype
    for a REAL exchange (collective payload stays compressed on the wire;
    the receiver casts back and accumulates fp32).  Identity for f32 — the
    one shared home of the cast the legacy ``launch.consensus_opt`` helpers
    each duplicated."""
    wd = canonical_wire_dtype(wire_dtype)
    if jnp.dtype(wd) == jnp.dtype(prec.dtype):
        return prec, pm
    return prec.astype(wd), pm.astype(wd)


def softplus(x: jax.Array) -> jax.Array:
    return jax.nn.softplus(x)


def softplus_inv(y: jax.Array) -> jax.Array:
    """Inverse of softplus for y > 0: x s.t. log1p(exp(x)) == y.

    Stable form ``y + log(-expm1(-y))`` — see module docstring for why
    ``expm1`` (and not ``log1p(-exp(.))``) is required at tiny y.
    """
    return y + jnp.log(-jnp.expm1(-y))


def softplus_inv_py(y: float) -> float:
    """Pure-Python softplus^-1 (same formulation) for use at trace time /
    under ``jax.eval_shape`` where no jnp ops may run (dry-run path)."""
    return y + math.log(-math.expm1(-y))
