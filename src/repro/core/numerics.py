"""Shared numerical primitives for Gaussian posteriors.

Single home for the softplus/softplus^-1 pair so the Pallas kernels, the
pure-jnp reference paths, and the core posterior code all use the SAME
stable formulation (previously the kernel inlined its own copy — satellite
fix of ISSUE 1).

``softplus_inv`` is stable over the full fp32 range of sigma:

* tiny y (sigma -> 0): softplus_inv(y) = log(expm1(y)) ~= log(y); the naive
  ``y + log1p(-exp(-y))`` form computes log1p(-exp(-eps)) which underflows
  ``-exp(-y)`` to -1 and returns -inf one ulp too early.  We use
  ``log(-expm1(-y)) + y`` which keeps full precision down to y ~ 1e-38.
* huge y (sigma >> 1): exp(-y) underflows to 0 and the result is exactly y,
  which is the correct asymptote (softplus(x) -> x for large x).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# canonical compute dtype for flat posterior buffers and kernel wrappers
COMPUTE_DTYPE = jnp.float32


def softplus(x: jax.Array) -> jax.Array:
    return jax.nn.softplus(x)


def softplus_inv(y: jax.Array) -> jax.Array:
    """Inverse of softplus for y > 0: x s.t. log1p(exp(x)) == y.

    Stable form ``y + log(-expm1(-y))`` — see module docstring for why
    ``expm1`` (and not ``log1p(-exp(.))``) is required at tiny y.
    """
    return y + jnp.log(-jnp.expm1(-y))


def softplus_inv_py(y: float) -> float:
    """Pure-Python softplus^-1 (same formulation) for use at trace time /
    under ``jax.eval_shape`` where no jnp ops may run (dry-run path)."""
    return y + math.log(-math.expm1(-y))
