"""Finite-Theta decentralized learning rule (the exact setting of Theorem 1).

With Theta finite and Q = P(Theta) the projection step (eq. 3) is the
identity, so one round at agent i is exactly:

  local Bayesian update (eq. 2):
      log b_i(theta) = log q_i(theta) + sum_{m in batch} log l_i(y_m | theta, x_m)
      (then normalize)
  consensus (eq. 4):
      log q_i(theta) = sum_j W_ij log b_j(theta)   (then normalize)

Everything is carried in log-space; beliefs have shape [N, |Theta|].
This module is the testbed that validates Theorem 1's exponential decay rate
K(Theta) empirically (tests/test_theory.py, benchmarks/thm1_rate.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def normalize_log(logb: jax.Array) -> jax.Array:
    """Normalize log-beliefs along the last (Theta) axis."""
    return logb - jax.nn.logsumexp(logb, axis=-1, keepdims=True)


def local_bayes_update(logq: jax.Array, loglik: jax.Array) -> jax.Array:
    """Eq. (2) in log space.

    logq:   [N, T] current private posteriors
    loglik: [N, T] sum over the agent's batch of log l_i(y|theta, x)
    returns [N, T] public posteriors b_i^{(n)}
    """
    return normalize_log(logq + loglik)


def consensus_update(logb: jax.Array, W: jax.Array) -> jax.Array:
    """Eq. (4) in log space: log q_i = sum_j W_ij log b_j (then normalize)."""
    return normalize_log(W @ logb)


def social_learning_round(
    logq: jax.Array, loglik: jax.Array, W: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One full round; returns (new_logq, logb)."""
    logb = local_bayes_update(logq, loglik)
    logq_new = consensus_update(logb, W)
    return logq_new, logb


def run_social_learning(
    key: jax.Array,
    W: jax.Array,
    loglik_sampler: Callable[[jax.Array], jax.Array],
    n_rounds: int,
    n_theta: int,
) -> jax.Array:
    """Run ``n_rounds`` rounds from the uniform prior.

    loglik_sampler(key) -> [N, T] batch log-likelihoods for one round.
    Returns the trajectory of public posteriors logb: [n_rounds, N, T].
    """
    n_agents = W.shape[0]
    logq0 = jnp.full((n_agents, n_theta), -jnp.log(n_theta))

    def step(carry, k):
        logq = carry
        loglik = loglik_sampler(k)
        logq_new, logb = social_learning_round(logq, loglik, W)
        return logq_new, logb

    keys = jax.random.split(key, n_rounds)
    _, traj = jax.lax.scan(step, logq0, keys)
    return traj


def wrong_belief_trajectory(traj_logb: jax.Array, wrong_idx: jax.Array) -> jax.Array:
    """max_i max_{theta in wrong set} b_i^{(n)}(theta) per round — the LHS of
    Theorem 1's bound.  traj_logb: [R, N, T]; wrong_idx: [k] indices."""
    wrong = traj_logb[..., wrong_idx]  # [R, N, k]
    return jnp.exp(jnp.max(wrong, axis=(1, 2)))
