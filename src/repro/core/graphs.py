"""Communication-network topologies and social-interaction matrices W.

The paper (Sec 2) models the network as a directed graph with a
row-stochastic weight matrix W: W_ij > 0 iff j in N(i) (i receives from j),
sum_j W_ij = 1, and i in N(i).  Assumption 1 requires W irreducible and
aperiodic.  Every builder here returns a row-stochastic numpy/jnp array and
is validated by ``check_w``.

Topologies used in the paper's experiments:
  * star (Sec 4.2.1): central agent 0 uniform over all; edge agent i puts
    confidence ``a`` on the center and 1-a on itself.
  * grid 3x3 (Sec 4.2.2): W_ij = 1/|N(i)| (degree-uniform).
  * time-varying star (Sec 1.4.3): at round t only N0 edge agents are
    connected to agent 0; union over the schedule is strongly connected.
Plus general builders (ring, torus, complete, erdos) for the framework.

Sparse-first representation
---------------------------
``SparseGraph`` is the edge-native counterpart: CSR-style ``indptr`` /
``indices`` / ``weights`` over directed IN-edges (row i lists the sources j
with W_ij > 0, self-loop included), row-stochastic by construction.  The
sparse builders (``ring_sparse``, ``grid_sparse``, ``torus_sparse``,
``star_sparse``, ``bidirectional_ring_sparse``) and the small-world
generators (``watts_strogatz_sparse``, ``barabasi_albert_sparse``) never
materialize ``[N, N]`` — peak host memory is O(E).  Assumption 1 is
validated by ``strongly_connected_csr``, an iterative (frontier-BFS)
Kosaraju check directly on the CSR arrays: reachability from node 0 in the
support graph AND in its counting-sort transpose — no networkx, no dense
conversion, no recursion.  ``to_dense()`` / ``from_dense()`` bridge to the
dense builders so every existing W interops; the dense validators
(``check_w`` / ``check_schedule_union``) now route through the same sparse
checker.
"""
from __future__ import annotations

import dataclasses

import numpy as np


# ---------------------------------------------------------------------------
# Iterative strong-connectivity check on CSR arrays (Assumption 1)
# ---------------------------------------------------------------------------


def _csr_transpose(indptr: np.ndarray, indices: np.ndarray, n: int):
    """Transpose a CSR support graph via a stable counting sort: O(E)."""
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    order = np.argsort(indices, kind="stable")
    t_indices = rows[order]
    t_indptr = np.zeros(n + 1, dtype=np.int64)
    t_indptr[1:] = np.cumsum(np.bincount(indices, minlength=n))
    return t_indptr, t_indices


def _reaches_all(indptr: np.ndarray, indices: np.ndarray, n: int) -> bool:
    """Does node 0 reach every node?  Iterative frontier BFS, no recursion."""
    seen = np.zeros(n, dtype=bool)
    seen[0] = True
    frontier = np.array([0], dtype=np.int64)
    visited = 1
    while frontier.size:
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        # Gather every frontier row's neighbor slice in one vectorized pass:
        # position k of the flat gather reads indices[starts[r] + offset]
        # where r is k's row and offset is k's rank within that row.
        row_of = np.repeat(np.arange(frontier.size), counts)
        offsets = np.arange(total) - np.repeat(
            np.cumsum(counts) - counts, counts
        )
        nbrs = indices[starts[row_of] + offsets]
        fresh = np.unique(nbrs[~seen[nbrs]])
        if fresh.size == 0:
            break
        seen[fresh] = True
        visited += fresh.size
        frontier = fresh
    return visited == n


def strongly_connected_csr(
    indptr: np.ndarray, indices: np.ndarray, n: int | None = None
) -> bool:
    """Is the digraph described by CSR ``indptr``/``indices`` strongly
    connected?

    Iterative Kosaraju-style check: strong connectivity holds iff node 0
    reaches every node in the support graph AND in its transpose.  Works on
    either edge orientation (strong connectivity is invariant under
    transposition); here the convention is rows = in-edges, matching
    ``SparseGraph``.  Pure numpy, O(E) time and memory, no recursion — safe
    at N = 10^5+ where both ``sys.setrecursionlimit`` DFS and a dense
    ``[N, N]`` conversion would fall over.
    """
    indptr = np.asarray(indptr, dtype=np.int64)
    indices = np.asarray(indices, dtype=np.int64)
    if n is None:
        n = indptr.shape[0] - 1
    if n <= 1:
        return True
    if indices.size == 0:
        return False
    if not _reaches_all(indptr, indices, n):
        return False
    t_indptr, t_indices = _csr_transpose(indptr, indices, n)
    return _reaches_all(t_indptr, t_indices, n)


# ---------------------------------------------------------------------------
# SparseGraph: edge-native row-stochastic topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparseGraph:
    """CSR-style row-stochastic directed graph over IN-edges.

    Row i of the CSR (``indices[indptr[i]:indptr[i+1]]``) lists the source
    agents j that agent i listens to (W_ij > 0), self-loop included;
    ``weights`` holds the matching W_ij.  This is the native representation
    for every O(E) code path: segment-sum consensus
    (``core.flat.consensus_flat_segments``), padded neighbor tables for the
    Pallas sparse kernels, and the E-parameterized rooflines.  ``to_dense``
    exists as an interop bridge only — the builders here never allocate
    ``[N, N]``.
    """

    indptr: np.ndarray  # [N + 1] int64, monotone
    indices: np.ndarray  # [E] int32 source ids, ascending within each row
    weights: np.ndarray  # [E] float64 W_ij, rows sum to 1

    @property
    def n_agents(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_edges(self) -> int:
        """Directed edge count INCLUDING self-loops (CSR nnz)."""
        return int(self.indices.shape[0])

    @property
    def in_degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def max_in_degree(self) -> int:
        return int(self.in_degrees.max()) if self.n_agents else 0

    def row(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """(sources, weights) of agent i's in-edges."""
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.weights[lo:hi]

    @classmethod
    def from_dense(cls, W: np.ndarray) -> "SparseGraph":
        """Bridge from any dense row-stochastic W (no validation here —
        call ``validate()`` for the Assumption-1 checks)."""
        Wn = np.asarray(W, dtype=np.float64)
        n = Wn.shape[0]
        if Wn.shape != (n, n):
            raise ValueError(f"W must be square, got {Wn.shape}")
        rows = [np.nonzero(Wn[i])[0] for i in range(n)]
        indptr = np.zeros(n + 1, dtype=np.int64)
        indptr[1:] = np.cumsum([len(r) for r in rows])
        indices = (
            np.concatenate(rows).astype(np.int32)
            if n
            else np.zeros(0, np.int32)
        )
        weights = (
            np.concatenate([Wn[i, r] for i, r in enumerate(rows)])
            if n
            else np.zeros(0, np.float64)
        )
        return cls(indptr=indptr, indices=indices, weights=weights)

    def to_dense(self) -> np.ndarray:
        """Interop bridge: materialize the dense [N, N] W.  Only call this
        below the spec size guard — it is the one place the sparse path is
        allowed to go quadratic."""
        n = self.n_agents
        W = np.zeros((n, n), dtype=np.float64)
        dst = np.repeat(np.arange(n, dtype=np.int64), self.in_degrees)
        W[dst, self.indices] = self.weights
        return W

    def edge_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Flat [E] edge arrays (dst, src, w) for segment-sum consensus.

        Self-loops are included — ``consensus_flat_segments`` needs no
        separate diagonal term.  dst/src are int32, w is float32 (the
        weights' compute dtype at the kernel boundary).
        """
        dst = np.repeat(
            np.arange(self.n_agents, dtype=np.int32),
            self.in_degrees.astype(np.int64),
        )
        return dst, self.indices.astype(np.int32), self.weights.astype(np.float32)

    def neighbor_tables(self) -> tuple[np.ndarray, np.ndarray]:
        """Padded [N, D] neighbor tables for ``consensus_fused_sparse``.

        Identical contract (and bit pattern) to the historical dense-W
        extraction: D = max in-degree, ragged rows padded with the agent's
        own id at weight 0.0, weights cast to float32.  This is THE one CSR
        construction behind ``core.flat.neighbor_tables``,
        ``neighbor_lists`` and ``max_in_degree``.
        """
        n, d = self.n_agents, max(self.max_in_degree, 1)
        neighbors = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, d))
        weights = np.zeros((n, d), np.float32)
        for i in range(n):
            lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
            neighbors[i, : hi - lo] = self.indices[lo:hi]
            weights[i, : hi - lo] = self.weights[lo:hi]
        return neighbors, weights

    def strongly_connected(self) -> bool:
        return strongly_connected_csr(self.indptr, self.indices, self.n_agents)

    def validate(self, *, require_connected: bool = True) -> None:
        """Assumption-1 prerequisites, sparse edition: the exact checks of
        ``check_w`` without ever leaving O(E) memory."""
        n = self.n_agents
        if self.indptr.shape != (n + 1,) or int(self.indptr[0]) != 0:
            raise ValueError("indptr must be [N+1] starting at 0")
        if np.any(np.diff(self.indptr) < 0):
            raise ValueError("indptr must be monotone")
        if int(self.indptr[-1]) != self.indices.shape[0]:
            raise ValueError("indptr[-1] must equal len(indices)")
        if self.weights.shape != self.indices.shape:
            raise ValueError("weights and indices must be the same length")
        if self.indices.size and (
            int(self.indices.min()) < 0 or int(self.indices.max()) >= n
        ):
            raise ValueError("edge sources out of range")
        if np.any(self.weights < 0):
            raise ValueError("W must be nonnegative")
        row_sums = np.zeros(n)
        dst = np.repeat(np.arange(n), self.in_degrees)
        np.add.at(row_sums, dst, self.weights)
        if not np.allclose(row_sums, 1.0, atol=1e-6):
            raise ValueError("W must be row-stochastic")
        has_self = np.zeros(n, dtype=bool)
        has_self[dst[(dst == self.indices) & (self.weights > 0)]] = True
        if not has_self.all():
            raise ValueError("self-loops required: i in N(i) (W_ii > 0)")
        if require_connected and not self.strongly_connected():
            raise ValueError("W's support graph must be strongly connected")


def _graph_from_rows(rows: list[list[int]], row_weights=None) -> SparseGraph:
    """Assemble a SparseGraph from per-agent in-neighbor lists.

    Each row is sorted ascending (matching ``np.nonzero`` order on the dense
    bridge); ``row_weights`` defaults to degree-uniform 1/|N(i)|.
    """
    n = len(rows)
    indptr = np.zeros(n + 1, dtype=np.int64)
    idx_parts, w_parts = [], []
    for i, r in enumerate(rows):
        order = np.argsort(r, kind="stable")
        r_arr = np.asarray(r, dtype=np.int32)[order]
        if row_weights is None:
            w_arr = np.full(len(r), 1.0 / len(r), dtype=np.float64)
        else:
            w_arr = np.asarray(row_weights[i], dtype=np.float64)[order]
        indptr[i + 1] = indptr[i] + len(r)
        idx_parts.append(r_arr)
        w_parts.append(w_arr)
    return SparseGraph(
        indptr=indptr,
        indices=np.concatenate(idx_parts) if n else np.zeros(0, np.int32),
        weights=np.concatenate(w_parts) if n else np.zeros(0, np.float64),
    )


# ---------------------------------------------------------------------------
# Sparse builders: the named topologies without the [N, N] allocation
# ---------------------------------------------------------------------------


def ring_sparse(n: int, self_weight: float = 0.5) -> SparseGraph:
    """Directed ring with self-loops: i listens to i-1 and itself.  Edge
    arrays only — ``ring_sparse(n).to_dense()`` equals ``ring_w(n)``."""
    # weights are aligned with the unsorted source list [(i-1) % n, i];
    # _graph_from_rows re-sorts both together, so row 0 ([n-1, 0]) lands
    # as sources [0, n-1] with weights [self_weight, 1 - self_weight].
    rows = [[(i - 1) % n, i] for i in range(n)]
    w = [[1.0 - self_weight, self_weight] for _ in range(n)]
    if n == 1:
        rows, w = [[0]], [[1.0]]
    g = _graph_from_rows(rows, w)
    g.validate()
    return g


def bidirectional_ring_sparse(n: int, self_weight: float = 1.0 / 3.0) -> SparseGraph:
    side = (1.0 - self_weight) / 2.0
    rows, w = [], []
    for i in range(n):
        trio = {(i - 1) % n: side, i: self_weight}
        trio[(i + 1) % n] = trio.get((i + 1) % n, 0.0) + side
        srcs = sorted(trio)
        rows.append(srcs)
        w.append([trio[j] for j in srcs])
    g = _graph_from_rows(rows, w)
    g.validate()
    return g


def _lattice_rows(rows: int, cols: int, wrap: bool) -> list[list[int]]:
    out = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = [i]
            if wrap:
                nbrs += [
                    ((r - 1) % rows) * cols + c,
                    ((r + 1) % rows) * cols + c,
                    r * cols + (c - 1) % cols,
                    r * cols + (c + 1) % cols,
                ]
            else:
                if r > 0:
                    nbrs.append((r - 1) * cols + c)
                if r < rows - 1:
                    nbrs.append((r + 1) * cols + c)
                if c > 0:
                    nbrs.append(r * cols + c - 1)
                if c < cols - 1:
                    nbrs.append(r * cols + c + 1)
            out.append(sorted(dict.fromkeys(nbrs)))
    return out


def grid_sparse(rows: int, cols: int) -> SparseGraph:
    """Paper Sec 4.2.2 grid, degree-uniform, CSR-native."""
    g = _graph_from_rows(_lattice_rows(rows, cols, wrap=False))
    g.validate()
    return g


def torus_sparse(rows: int, cols: int) -> SparseGraph:
    """2-D torus, degree-uniform (the natural TPU-ICI-shaped topology)."""
    g = _graph_from_rows(_lattice_rows(rows, cols, wrap=True))
    g.validate()
    return g


def star_sparse(n_edge: int, a: float) -> SparseGraph:
    """Paper Sec 4.2.1 star in CSR form (center row uniform, edge rows
    (a, 1-a))."""
    n = n_edge + 1
    rows = [list(range(n))] + [[0, i] for i in range(1, n)]
    w = [[1.0 / n] * n] + [[a, 1.0 - a] for _ in range(1, n)]
    g = _graph_from_rows(rows, w)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Small-world generators (sparse-only: these are the N = 10^4+ topologies)
# ---------------------------------------------------------------------------


def _graph_from_neighbor_sets(nbrs: list[set[int]]) -> SparseGraph:
    """Symmetric support + self-loops, degree-uniform weights."""
    rows = [sorted(s | {i}) for i, s in enumerate(nbrs)]
    return _graph_from_rows(rows)


def watts_strogatz_sparse(
    n: int, k: int = 6, beta: float = 0.1, seed: int = 0, attempts: int = 100
) -> SparseGraph:
    """Watts-Strogatz small-world graph, degree-uniform row-stochastic.

    Ring lattice with k/2 neighbors each side, each lattice edge rewired
    with probability ``beta`` (no self-edges, no duplicates); the support is
    kept symmetric, so strong connectivity = undirected connectivity.
    Rewiring can disconnect the graph, so samples are drawn from the
    ``(seed, attempt)`` stream until the iterative CSR check passes.  Never
    allocates ``[N, N]``.
    """
    if k <= 0 or k % 2:
        raise ValueError(f"watts_strogatz_sparse: k must be positive and even, got {k}")
    if k >= n:
        raise ValueError(f"watts_strogatz_sparse: need k < n, got k={k}, n={n}")
    if not 0.0 <= beta <= 1.0:
        raise ValueError(f"watts_strogatz_sparse: beta must be in [0, 1], got {beta}")
    for attempt in range(attempts):
        rng = np.random.default_rng([seed, attempt])
        nbrs: list[set[int]] = [set() for _ in range(n)]
        for off in range(1, k // 2 + 1):
            for i in range(n):
                j = (i + off) % n
                nbrs[i].add(j)
                nbrs[j].add(i)
        for off in range(1, k // 2 + 1):
            for i in range(n):
                j = (i + off) % n
                if rng.random() < beta and j in nbrs[i] and len(nbrs[i]) < n - 1:
                    while True:
                        t = int(rng.integers(n))
                        if t != i and t not in nbrs[i]:
                            break
                    nbrs[i].discard(j)
                    nbrs[j].discard(i)
                    nbrs[i].add(t)
                    nbrs[t].add(i)
        g = _graph_from_neighbor_sets(nbrs)
        if g.strongly_connected():
            g.validate()
            return g
    raise RuntimeError(
        f"watts_strogatz_sparse: no connected sample after {attempts} attempts "
        f"(n={n}, k={k}, beta={beta}, seed={seed}); raise k or lower beta"
    )


def _random_subset(repeated: list[int], m: int, rng) -> list[int]:
    chosen: set[int] = set()
    while len(chosen) < m:
        chosen.add(repeated[int(rng.integers(len(repeated)))])
    return sorted(chosen)


def barabasi_albert_sparse(n: int, m: int = 3, seed: int = 0) -> SparseGraph:
    """Barabasi-Albert preferential attachment, degree-uniform row-stochastic.

    Standard repeated-nodes construction: node ``m`` attaches to the m seed
    nodes, every later node to m distinct targets drawn proportionally to
    degree.  The undirected support is connected by construction, so no
    resampling loop is needed; symmetrized + self-loops it satisfies
    Assumption 1 directly.  O(E) memory throughout.
    """
    if m < 1 or m >= n:
        raise ValueError(f"barabasi_albert_sparse: need 1 <= m < n, got m={m}, n={n}")
    rng = np.random.default_rng(seed)
    nbrs: list[set[int]] = [set() for _ in range(n)]
    targets = list(range(m))
    repeated: list[int] = []
    for source in range(m, n):
        for t in targets:
            nbrs[source].add(t)
            nbrs[t].add(source)
        repeated.extend(targets)
        repeated.extend([source] * m)
        targets = _random_subset(repeated, m, rng)
    g = _graph_from_neighbor_sets(nbrs)
    g.validate()
    return g


#: Registry for ``TopologySpec(kind="sparse")``: generator name -> builder.
#: Every builder returns a validated ``SparseGraph`` and never goes O(N^2).
SPARSE_GENERATORS = {
    "ring": ring_sparse,
    "bidirectional_ring": bidirectional_ring_sparse,
    "grid": grid_sparse,
    "torus": torus_sparse,
    "star": star_sparse,
    "watts_strogatz": watts_strogatz_sparse,
    "barabasi_albert": barabasi_albert_sparse,
}


def build_sparse(generator: str, **params) -> SparseGraph:
    """Build a named sparse topology (the ``TopologySpec(kind="sparse")``
    entry point)."""
    if generator not in SPARSE_GENERATORS:
        raise ValueError(
            f"unknown sparse generator {generator!r}; "
            f"choose from {sorted(SPARSE_GENERATORS)}"
        )
    return SPARSE_GENERATORS[generator](**params)


def watts_strogatz_w(n: int, k: int = 6, beta: float = 0.1, seed: int = 0) -> np.ndarray:
    """Dense bridge for the Watts-Strogatz generator (named-topology /
    gossip-base interop; use ``watts_strogatz_sparse`` at scale)."""
    return watts_strogatz_sparse(n, k=k, beta=beta, seed=seed).to_dense()


def barabasi_albert_w(n: int, m: int = 3, seed: int = 0) -> np.ndarray:
    """Dense bridge for the Barabasi-Albert generator."""
    return barabasi_albert_sparse(n, m=m, seed=seed).to_dense()


# ---------------------------------------------------------------------------
# Dense builders + validators (interop surface; small N)
# ---------------------------------------------------------------------------


def check_w(W: np.ndarray, *, require_connected: bool = True) -> None:
    """Validate the paper's Assumption 1 prerequisites."""
    W = np.asarray(W)
    n = W.shape[0]
    if W.shape != (n, n):
        raise ValueError(f"W must be square, got {W.shape}")
    if np.any(W < 0):
        raise ValueError("W must be nonnegative")
    if not np.allclose(W.sum(axis=1), 1.0, atol=1e-6):
        raise ValueError("W must be row-stochastic")
    if np.any(np.diag(W) <= 0):
        raise ValueError("self-loops required: i in N(i) (W_ii > 0)")
    if require_connected:
        g = SparseGraph.from_dense(W)
        if not g.strongly_connected():
            raise ValueError("W's support graph must be strongly connected")


def star_w(n_edge: int, a: float) -> np.ndarray:
    """Paper Sec 4.2.1: star with agent 0 at the center and ``n_edge`` edge
    agents.  Center row uniform 1/(n_edge+1); edge agent i puts ``a`` on the
    center and 1-a on itself."""
    n = n_edge + 1
    W = np.zeros((n, n))
    W[0, :] = 1.0 / n
    for i in range(1, n):
        W[i, 0] = a
        W[i, i] = 1.0 - a
    check_w(W)
    return W


def grid_w(rows: int, cols: int) -> np.ndarray:
    """Paper Sec 4.2.2: grid with degree-uniform weights W_ij = 1/|N(i)|
    (self-loop included in N(i))."""
    n = rows * cols
    W = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = [i]
            if r > 0:
                nbrs.append((r - 1) * cols + c)
            if r < rows - 1:
                nbrs.append((r + 1) * cols + c)
            if c > 0:
                nbrs.append(r * cols + c - 1)
            if c < cols - 1:
                nbrs.append(r * cols + c + 1)
            for j in nbrs:
                W[i, j] = 1.0 / len(nbrs)
    check_w(W)
    return W


def ring_w(n: int, self_weight: float = 0.5) -> np.ndarray:
    """Directed ring with self-loops: i listens to i-1 and itself."""
    W = np.zeros((n, n))
    for i in range(n):
        W[i, i] = self_weight
        W[i, (i - 1) % n] = 1.0 - self_weight
    check_w(W)
    return W


def bidirectional_ring_w(n: int, self_weight: float = 1.0 / 3.0) -> np.ndarray:
    W = np.zeros((n, n))
    side = (1.0 - self_weight) / 2.0
    for i in range(n):
        W[i, i] = self_weight
        W[i, (i - 1) % n] = side
        W[i, (i + 1) % n] = side
    check_w(W)
    return W


def torus_w(rows: int, cols: int) -> np.ndarray:
    """2-D torus, degree-uniform (the natural TPU-ICI-shaped topology)."""
    n = rows * cols
    W = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = [
                i,
                ((r - 1) % rows) * cols + c,
                ((r + 1) % rows) * cols + c,
                r * cols + (c - 1) % cols,
                r * cols + (c + 1) % cols,
            ]
            nbrs = list(dict.fromkeys(nbrs))
            for j in nbrs:
                W[i, j] = 1.0 / len(nbrs)
    check_w(W)
    return W


def complete_w(n: int) -> np.ndarray:
    """Fully connected, uniform weights (centralized-equivalent baseline)."""
    W = np.full((n, n), 1.0 / n)
    check_w(W)
    return W


def erdos_w(n: int, p: float, seed: int = 0, attempts: int = 1000) -> np.ndarray:
    """Erdos-Renyi digraph (resampled until strongly connected), degree-uniform
    weights with self-loops.

    Each attempt is screened by the iterative CSR connectivity check (no
    per-attempt networkx graph); on exhaustion the error reports the actual
    ``(n, p, attempts)`` and the connectivity threshold ``p >~ log(n)/n``
    below which strongly connected samples are exponentially rare.
    """
    rng = np.random.default_rng(seed)
    for _ in range(attempts):
        adj = (rng.random((n, n)) < p).astype(float)
        np.fill_diagonal(adj, 1.0)
        if SparseGraph.from_dense(adj).strongly_connected():
            W = adj / adj.sum(axis=1, keepdims=True)
            check_w(W)
            return W
    threshold = np.log(n) / n if n > 1 else 0.0
    raise RuntimeError(
        f"erdos_w: could not sample a strongly connected graph with n={n}, "
        f"p={p} after {attempts} attempts; directed G(n, p) is a.s. "
        f"disconnected below the threshold p ~ log(n)/n = {threshold:.4g} — "
        f"raise p (or n)"
    )


def check_schedule_union(mats) -> None:
    """Time-varying relaxation of Assumption 1: each slot need not be
    connected, but the UNION of the schedule's support graphs must be
    strongly connected."""
    union = (sum((np.asarray(m) > 0).astype(float) for m in mats) > 0).astype(float)
    if not SparseGraph.from_dense(union).strongly_connected():
        raise ValueError("union of the W schedule must be strongly connected")


def time_varying_star_schedule(
    n_agents: int, n_active: int, a: float = 0.5
) -> list[np.ndarray]:
    """Paper Sec 1.4.3: N+1 agents {0..N}; at slot k only agents
    {N0(k-1)+1, ..., N0 k} are connected to the center 0 in a star.
    Inactive agents keep W_ii = 1 (train locally / idle).  The union over the
    schedule is strongly connected.  Returns the list of per-slot W's."""
    if n_agents % n_active != 0:
        raise ValueError("n_agents must be divisible by n_active")
    n = n_agents + 1
    mats = []
    for k in range(n_agents // n_active):
        W = np.eye(n)
        active = list(range(n_active * k + 1, n_active * (k + 1) + 1))
        W[0, 0] = 1.0 / (n_active + 1)
        for j in active:
            W[0, j] = 1.0 / (n_active + 1)
            W[j, 0] = a
            W[j, j] = 1.0 - a
        check_w(W, require_connected=False)
        mats.append(W)
    check_schedule_union(mats)
    return mats


def neighbor_lists(W: np.ndarray) -> list[list[int]]:
    """In-neighbors per agent (j such that W_ij > 0), including self.

    Routed through the one CSR construction (``SparseGraph.from_dense``) so
    this, ``max_in_degree`` and ``core.flat.neighbor_tables`` can never
    disagree on ordering or support."""
    g = SparseGraph.from_dense(W)
    return [[int(j) for j in g.row(i)[0]] for i in range(g.n_agents)]


def max_in_degree(W: np.ndarray) -> int:
    return SparseGraph.from_dense(W).max_in_degree
