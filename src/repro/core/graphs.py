"""Communication-network topologies and social-interaction matrices W.

The paper (Sec 2) models the network as a directed graph with a
row-stochastic weight matrix W: W_ij > 0 iff j in N(i) (i receives from j),
sum_j W_ij = 1, and i in N(i).  Assumption 1 requires W irreducible and
aperiodic.  Every builder here returns a row-stochastic numpy/jnp array and
is validated by ``check_w``.

Topologies used in the paper's experiments:
  * star (Sec 4.2.1): central agent 0 uniform over all; edge agent i puts
    confidence ``a`` on the center and 1-a on itself.
  * grid 3x3 (Sec 4.2.2): W_ij = 1/|N(i)| (degree-uniform).
  * time-varying star (Sec 1.4.3): at round t only N0 edge agents are
    connected to agent 0; union over the schedule is strongly connected.
Plus general builders (ring, torus, complete, erdos) for the framework.
"""
from __future__ import annotations

import numpy as np
import networkx as nx


def check_w(W: np.ndarray, *, require_connected: bool = True) -> None:
    """Validate the paper's Assumption 1 prerequisites."""
    W = np.asarray(W)
    n = W.shape[0]
    if W.shape != (n, n):
        raise ValueError(f"W must be square, got {W.shape}")
    if np.any(W < 0):
        raise ValueError("W must be nonnegative")
    if not np.allclose(W.sum(axis=1), 1.0, atol=1e-6):
        raise ValueError("W must be row-stochastic")
    if np.any(np.diag(W) <= 0):
        raise ValueError("self-loops required: i in N(i) (W_ii > 0)")
    if require_connected:
        g = nx.from_numpy_array((W > 0).astype(float), create_using=nx.DiGraph)
        if not nx.is_strongly_connected(g):
            raise ValueError("W's support graph must be strongly connected")


def star_w(n_edge: int, a: float) -> np.ndarray:
    """Paper Sec 4.2.1: star with agent 0 at the center and ``n_edge`` edge
    agents.  Center row uniform 1/(n_edge+1); edge agent i puts ``a`` on the
    center and 1-a on itself."""
    n = n_edge + 1
    W = np.zeros((n, n))
    W[0, :] = 1.0 / n
    for i in range(1, n):
        W[i, 0] = a
        W[i, i] = 1.0 - a
    check_w(W)
    return W


def grid_w(rows: int, cols: int) -> np.ndarray:
    """Paper Sec 4.2.2: grid with degree-uniform weights W_ij = 1/|N(i)|
    (self-loop included in N(i))."""
    n = rows * cols
    W = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = [i]
            if r > 0:
                nbrs.append((r - 1) * cols + c)
            if r < rows - 1:
                nbrs.append((r + 1) * cols + c)
            if c > 0:
                nbrs.append(r * cols + c - 1)
            if c < cols - 1:
                nbrs.append(r * cols + c + 1)
            for j in nbrs:
                W[i, j] = 1.0 / len(nbrs)
    check_w(W)
    return W


def ring_w(n: int, self_weight: float = 0.5) -> np.ndarray:
    """Directed ring with self-loops: i listens to i-1 and itself."""
    W = np.zeros((n, n))
    for i in range(n):
        W[i, i] = self_weight
        W[i, (i - 1) % n] = 1.0 - self_weight
    check_w(W)
    return W


def bidirectional_ring_w(n: int, self_weight: float = 1.0 / 3.0) -> np.ndarray:
    W = np.zeros((n, n))
    side = (1.0 - self_weight) / 2.0
    for i in range(n):
        W[i, i] = self_weight
        W[i, (i - 1) % n] = side
        W[i, (i + 1) % n] = side
    check_w(W)
    return W


def torus_w(rows: int, cols: int) -> np.ndarray:
    """2-D torus, degree-uniform (the natural TPU-ICI-shaped topology)."""
    n = rows * cols
    W = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = [
                i,
                ((r - 1) % rows) * cols + c,
                ((r + 1) % rows) * cols + c,
                r * cols + (c - 1) % cols,
                r * cols + (c + 1) % cols,
            ]
            nbrs = list(dict.fromkeys(nbrs))
            for j in nbrs:
                W[i, j] = 1.0 / len(nbrs)
    check_w(W)
    return W


def complete_w(n: int) -> np.ndarray:
    """Fully connected, uniform weights (centralized-equivalent baseline)."""
    W = np.full((n, n), 1.0 / n)
    check_w(W)
    return W


def erdos_w(n: int, p: float, seed: int = 0) -> np.ndarray:
    """Erdos-Renyi digraph (resampled until strongly connected), degree-uniform
    weights with self-loops."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        adj = (rng.random((n, n)) < p).astype(float)
        np.fill_diagonal(adj, 1.0)
        g = nx.from_numpy_array(adj, create_using=nx.DiGraph)
        if nx.is_strongly_connected(g):
            W = adj / adj.sum(axis=1, keepdims=True)
            check_w(W)
            return W
    raise RuntimeError("could not sample a strongly connected graph")


def check_schedule_union(mats) -> None:
    """Time-varying relaxation of Assumption 1: each slot need not be
    connected, but the UNION of the schedule's support graphs must be
    strongly connected."""
    union = (sum((np.asarray(m) > 0).astype(float) for m in mats) > 0).astype(float)
    g = nx.from_numpy_array(union, create_using=nx.DiGraph)
    if not nx.is_strongly_connected(g):
        raise ValueError("union of the W schedule must be strongly connected")


def time_varying_star_schedule(
    n_agents: int, n_active: int, a: float = 0.5
) -> list[np.ndarray]:
    """Paper Sec 1.4.3: N+1 agents {0..N}; at slot k only agents
    {N0(k-1)+1, ..., N0 k} are connected to the center 0 in a star.
    Inactive agents keep W_ii = 1 (train locally / idle).  The union over the
    schedule is strongly connected.  Returns the list of per-slot W's."""
    if n_agents % n_active != 0:
        raise ValueError("n_agents must be divisible by n_active")
    n = n_agents + 1
    mats = []
    for k in range(n_agents // n_active):
        W = np.eye(n)
        active = list(range(n_active * k + 1, n_active * (k + 1) + 1))
        W[0, 0] = 1.0 / (n_active + 1)
        for j in active:
            W[0, j] = 1.0 / (n_active + 1)
            W[j, 0] = a
            W[j, j] = 1.0 - a
        check_w(W, require_connected=False)
        mats.append(W)
    check_schedule_union(mats)
    return mats


def neighbor_lists(W: np.ndarray) -> list[list[int]]:
    """In-neighbors per agent (j such that W_ij > 0), including self."""
    return [list(np.nonzero(W[i] > 0)[0]) for i in range(W.shape[0])]


def max_in_degree(W: np.ndarray) -> int:
    return max(len(nb) for nb in neighbor_lists(W))
