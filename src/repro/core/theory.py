"""Theorem 1 quantities: eigenvector centrality, spectral gap, rate K(Theta),
and the sample-complexity bound.

    K(Theta) = min_{theta* in Theta*, theta notin Theta*} sum_j v_j I_j(theta*, theta)
    n >= 8 C log(N |Theta| / delta) / (eps^2 (1 - lambda_max(W)))

where v is the unique stationary distribution of W (v = v W), lambda_max is
the second-largest eigenvalue (by the paper's indexing lambda_0 = 1), and
C = |log(L/alpha)| bounds the log-likelihood ratios.
"""
from __future__ import annotations

import numpy as np


def stationary_distribution(W: np.ndarray) -> np.ndarray:
    """Unique stationary distribution v of the row-stochastic W: v = v W.

    (= eigenvector centrality of the agents, paper Remark 3.)
    """
    W = np.asarray(W, dtype=np.float64)
    vals, vecs = np.linalg.eig(W.T)
    idx = int(np.argmin(np.abs(vals - 1.0)))
    v = np.real(vecs[:, idx])
    v = v / v.sum()
    if np.any(v < -1e-9):
        raise ValueError("stationary distribution has negative entries; W not irreducible?")
    return np.clip(v, 0.0, None) / np.clip(v, 0.0, None).sum()


def lambda_max(W: np.ndarray) -> float:
    """Second-largest eigenvalue modulus of W (paper: max_{1<=i<=N-1} lambda_i,
    with lambda_0 = 1 excluded)."""
    vals = np.linalg.eigvals(np.asarray(W, dtype=np.float64))
    mags = np.sort(np.abs(vals))[::-1]
    # drop one eigenvalue equal to 1 (Perron root)
    return float(mags[1]) if len(mags) > 1 else 0.0


def spectral_gap(W: np.ndarray) -> float:
    return 1.0 - lambda_max(W)


def rate_K(v: np.ndarray, I: np.ndarray) -> float:
    """K(Theta) from eq. (7).

    I: array [N, n_star, n_wrong] of divergence gaps
       I[j, s, t] = I_j(theta*_s, theta_t)  (may be negative per-agent; the
       network sum must be positive under Assumption 2).
    """
    v = np.asarray(v)
    I = np.asarray(I)
    summed = np.einsum("j,jst->st", v, I)  # [n_star, n_wrong]
    return float(summed.min())


def sample_complexity(
    n_agents: int, n_theta: int, delta: float, eps: float, C: float, W: np.ndarray
) -> float:
    """Theorem 1 sample-size condition n >= 8C log(N|Theta|/delta) / (eps^2 gap)."""
    gap = spectral_gap(W)
    if gap <= 0:
        return float("inf")
    return 8.0 * C * np.log(n_agents * n_theta / delta) / (eps**2 * gap)


def gaussian_divergence_gap(
    mean_true: np.ndarray, mean_wrong: np.ndarray, noise_var: float
) -> float:
    """I_j(theta*, theta) in the realizable Gaussian-likelihood case:
    E[KL(N(f*(x), s^2) || N(f_theta(x), s^2))] = E[(f* - f_theta)^2] / (2 s^2).

    Arguments are per-sample predictions under theta* and theta; the mean over
    samples approximates the expectation over P_j.
    """
    diff = np.asarray(mean_true) - np.asarray(mean_wrong)
    return float(np.mean(diff**2) / (2.0 * noise_var))


def predicted_decay_curve(K: float, n: np.ndarray, eps: float = 0.0) -> np.ndarray:
    """Theorem 1 bound: max wrong-parameter belief < exp(-n (K - eps))."""
    return np.exp(-np.asarray(n) * (K - eps))


def consensus_contraction_rate(W: np.ndarray) -> float:
    """Per-round exponential decay rate of network DISAGREEMENT under
    repeated averaging with a static W: the disagreement component lives in
    the eigenspace orthogonal to the Perron root, so
    ``disagreement_n ~ lambda_max^n = exp(-n * rate)`` with
    ``rate = -log(lambda_max(W))``.

    This is the spectral (zero-learning) analogue of ``rate_K``: it feeds
    the same ``predicted_decay_curve(rate, n)`` overlay that the
    observability convergence tracker (``repro.obs.convergence``) compares
    measured disagreement decay against.  A disconnected W (lambda_max = 1)
    contracts nothing: rate 0.  A single pass of a complete uniform W
    (lambda_max = 0) contracts everything: rate inf.
    """
    lam = lambda_max(W)
    if lam >= 1.0:
        return 0.0
    # eigensolver noise: a uniform W's non-Perron eigenvalues come back as
    # ~1e-16 garbage, which -log would turn into a huge-but-finite rate
    if lam <= 1e-12:
        return float("inf")
    return float(-np.log(lam))
