"""Gaussian posteriors over model-parameter pytrees.

The paper restricts the per-agent posterior q_i to a tractable family Q
(Sec 2.1, step 3).  Two families are implemented:

* ``GaussianPosterior`` — mean-field (diagonal) Gaussian over an arbitrary
  parameter pytree.  This is the family used for all neural-network
  experiments in the paper (Bayes-by-Backprop, [10]).  sigma is
  parameterized as ``softplus(rho)`` for unconstrained optimization.

* ``FullCovGaussian`` — full-covariance Gaussian over a flat R^d parameter
  vector.  Used for the paper's Example 1 / Fig 1 (Bayesian linear
  regression, d=5), where the exact conjugate posterior is full-covariance.

Both support the closed-form consensus of eq. (6):
    prec_tilde_i = sum_j W_ij prec_j
    mu_tilde_i   = prec_tilde_i^{-1} sum_j W_ij prec_j mu_j
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.numerics import softplus, softplus_inv, softplus_inv_py

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GaussianPosterior:
    """Mean-field Gaussian over a parameter pytree.

    ``mean`` and ``rho`` are pytrees with identical structure; the stddev of
    each scalar parameter is ``softplus(rho)``.
    """

    mean: PyTree
    rho: PyTree

    def sigma(self) -> PyTree:
        return jax.tree.map(softplus, self.rho)

    def precision(self) -> PyTree:
        return jax.tree.map(lambda r: 1.0 / jnp.square(softplus(r)), self.rho)

    def sample(self, key: jax.Array) -> PyTree:
        """Reparameterized sample theta = mu + sigma * eps."""
        leaves, treedef = jax.tree.flatten(self.mean)
        keys = jax.random.split(key, len(leaves))
        rho_leaves = treedef.flatten_up_to(self.rho)
        out = [
            m + softplus(r) * jax.random.normal(k, m.shape, m.dtype)
            for m, r, k in zip(leaves, rho_leaves, keys)
        ]
        return jax.tree.unflatten(treedef, out)

    def n_params(self) -> int:
        return sum(int(l.size) for l in jax.tree.leaves(self.mean))


def init_posterior(
    params: PyTree, init_sigma: float = 0.05, mean_init: PyTree | None = None
) -> GaussianPosterior:
    """Build a mean-field posterior matching the structure of ``params``."""
    mean = params if mean_init is None else mean_init
    # pure-Python softplus^-1 so this works under jax.eval_shape (dry-run)
    rho0 = softplus_inv_py(init_sigma)
    rho = jax.tree.map(lambda p: jnp.full_like(p, rho0), params)
    return GaussianPosterior(mean=mean, rho=rho)


def kl_gaussian(q: GaussianPosterior, p: GaussianPosterior) -> jax.Array:
    """KL(q || p) between two mean-field Gaussians over the same pytree.

    Closed form, summed over every scalar parameter:
      KL = sum [ log(sp/sq) + (sq^2 + (mq-mp)^2) / (2 sp^2) - 1/2 ]
    """

    def leaf_kl(mq, rq, mp, rp):
        sq = softplus(rq)
        sp = softplus(rp)
        return jnp.sum(
            jnp.log(sp / sq) + (jnp.square(sq) + jnp.square(mq - mp)) / (2.0 * jnp.square(sp)) - 0.5
        )

    terms = jax.tree.map(leaf_kl, q.mean, q.rho, p.mean, p.rho)
    return jax.tree.reduce(jnp.add, terms, jnp.asarray(0.0))


def consensus_mean_field(
    posts: GaussianPosterior, w_row: jax.Array
) -> GaussianPosterior:
    """Consensus step (eq. 6) for ONE agent from stacked neighbor posteriors.

    ``posts`` has a leading axis of size N on every leaf (the neighbors,
    including self); ``w_row`` is the agent's row of W (shape [N], sums to 1).
    Zero-weight entries contribute nothing (sparse topologies).
    """

    def combine(mean_stack, rho_stack):
        prec = 1.0 / jnp.square(softplus(rho_stack))
        w = w_row.reshape((-1,) + (1,) * (mean_stack.ndim - 1))
        new_prec = jnp.sum(w * prec, axis=0)
        new_mean = jnp.sum(w * prec * mean_stack, axis=0) / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    out = [combine(m, r) for m, r in zip(flat_mean, flat_rho)]
    mean = jax.tree.unflatten(treedef, [m for m, _ in out])
    rho = jax.tree.unflatten(treedef, [r for _, r in out])
    return GaussianPosterior(mean=mean, rho=rho)


def consensus_all_agents(
    posts: GaussianPosterior, W: jax.Array, wire_dtype=None
) -> GaussianPosterior:
    """Consensus step (eq. 6) for ALL agents simultaneously.

    Every leaf of ``posts`` carries a leading agent axis of size N.  W is the
    [N, N] row-stochastic social-interaction matrix.  Returns posteriors with
    the same leading axis.  This is the simulated-runtime (vmap) path; the
    production path uses collectives (core.collectives).

    ``posts`` may be a ``GaussianPosterior`` over a parameter pytree (the
    paper-faithful leaf-loop reference below) or a ``core.flat.FlatPosterior``
    (contiguous [N, P] buffers), in which case the call dispatches to the
    single fused network-wide path (Pallas kernel on TPU, fused XLA einsum
    elsewhere) — one HBM pass over the whole network posterior per round.

    ``wire_dtype`` (``None`` | ``"f32"|"bf16"|"f16"`` | dtype) rounds the
    exchanged (prec, prec*mu) through the wire dtype at the exchange
    boundary on BOTH dispatch targets — f32/None is bitwise the
    uncompressed path (ROADMAP "Wire precision").
    """
    from repro.core.flat import FlatPosterior, consensus_flat
    from repro.core.numerics import wire_roundtrip

    if isinstance(posts, FlatPosterior):
        return consensus_flat(posts, W, wire_dtype=wire_dtype)

    def combine(mean_stack, rho_stack):
        prec = 1.0 / jnp.square(softplus(rho_stack))
        pm = prec * mean_stack
        prec_x = wire_roundtrip(prec, wire_dtype)
        pm_x = wire_roundtrip(pm, wire_dtype)
        # new_prec[i] = sum_j W[i,j] prec[j]
        new_prec = jnp.einsum("ij,j...->i...", W, prec_x)
        new_mean = jnp.einsum("ij,j...->i...", W, pm_x) / new_prec
        new_rho = softplus_inv(jnp.sqrt(1.0 / new_prec))
        return new_mean, new_rho

    flat_mean, treedef = jax.tree.flatten(posts.mean)
    flat_rho = treedef.flatten_up_to(posts.rho)
    out = [combine(m, r) for m, r in zip(flat_mean, flat_rho)]
    mean = jax.tree.unflatten(treedef, [m for m, _ in out])
    rho = jax.tree.unflatten(treedef, [r for _, r in out])
    return GaussianPosterior(mean=mean, rho=rho)


def consensus_mean_only(params: PyTree, W: jax.Array) -> PyTree:
    """Degenerate (delta-posterior) consensus: plain W-weighted parameter
    averaging.  This is the non-Bayesian baseline (decentralized FedAvg /
    local-SGD aggregation) the framework exposes for comparison."""
    return jax.tree.map(lambda p: jnp.einsum("ij,j...->i...", W, p), params)


# ---------------------------------------------------------------------------
# Full-covariance Gaussian over a flat parameter vector (paper Example 1)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FullCovGaussian:
    """Full-covariance Gaussian over theta in R^d, stored as (mean, precision).

    Storing the precision (Lambda = Sigma^{-1}) makes both the conjugate
    Bayesian linear-regression update and the consensus step (eq. 6) linear.
    """

    mean: jax.Array  # [d] (or [N, d] with leading agent axis)
    prec: jax.Array  # [d, d] (or [N, d, d])

    def cov(self) -> jax.Array:
        return jnp.linalg.inv(self.prec)

    def sample(self, key: jax.Array) -> jax.Array:
        cov = self.cov()
        chol = jnp.linalg.cholesky(cov)
        eps = jax.random.normal(key, self.mean.shape, self.mean.dtype)
        return self.mean + jnp.einsum("...ij,...j->...i", chol, eps)


def linreg_bayes_update(
    post: FullCovGaussian, phi: jax.Array, y: jax.Array, noise_var: float
) -> FullCovGaussian:
    """Exact conjugate local Bayesian update (paper eq. 2) for the linear
    model y = theta^T phi(x) + eta, eta ~ N(0, noise_var).

    phi: [B, d] feature matrix, y: [B] labels.
    """
    prec_new = post.prec + jnp.einsum("bi,bj->ij", phi, phi) / noise_var
    rhs = post.prec @ post.mean + phi.T @ y / noise_var
    mean_new = jnp.linalg.solve(prec_new, rhs)
    return FullCovGaussian(mean=mean_new, prec=prec_new)


def consensus_full_cov(posts: FullCovGaussian, W: jax.Array) -> FullCovGaussian:
    """Eq. (6) over stacked full-covariance posteriors (leading agent axis)."""
    prec_new = jnp.einsum("ij,jkl->ikl", W, posts.prec)
    rhs = jnp.einsum("ij,jkl,jl->ik", W, posts.prec, posts.mean)
    mean_new = jnp.linalg.solve(prec_new, rhs[..., None])[..., 0]
    return FullCovGaussian(mean=mean_new, prec=prec_new)
